"""Quickstart: the HASTILY technique in five minutes (pure CPU).

1. the UCLM LUT exponential and its paper error bounds;
2. LUT softmax == exact softmax to ~1e-5;
3. fine-grained-pipelined (streaming) attention == materialised attention,
   with the jaxpr proof that the l×l logit matrix never exists;
4. a reduced assigned-architecture model doing a forward/loss step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (lut_exp, lut_softmax, naive_attention,
                        streaming_attention)
from repro.configs import get_config
from repro.models import build_model


def main():
    print("== 1. UCLM LUT exponential (paper §III-B1) ==")
    x = jnp.linspace(-20, 20, 100_001)
    for order, bound in ((0, 0.54), (1, 0.0015)):
        rel = np.max(np.abs(np.asarray(lut_exp(x, order=order))
                            / np.exp(np.asarray(x)) - 1))
        print(f"  order {order}: max rel err {rel * 100:.5f}%  "
              f"(paper bound {bound}%)")

    print("\n== 2. LUT softmax vs exact ==")
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)) * 8,
                         jnp.float32)
    d = np.max(np.abs(np.asarray(lut_softmax(logits))
                      - np.asarray(jax.nn.softmax(logits))))
    print(f"  max |lut_softmax - softmax| = {d:.2e}")

    print("\n== 3. streaming attention: O(l) memory (paper §IV) ==")
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 4, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 4, 256, 32)).astype(np.float32))
    out_s = streaming_attention(q, k, v, causal=True, block_k=64)
    out_n = naive_attention(q, k, v, causal=True, exp_mode="lut")
    print(f"  streaming == naive: max diff "
          f"{float(jnp.max(jnp.abs(out_s - out_n))):.2e}")

    jaxpr = jax.make_jaxpr(lambda a, b, c: streaming_attention(
        a, b, c, causal=True, block_k=64))(q, k, v)

    def biggest(eqns, best=0):
        for eq in eqns:
            for var in eq.outvars:
                shape = getattr(var.aval, "shape", ())
                n = sum(1 for s in shape if s == 256)
                best = max(best, n)
            for sub in eq.params.values():
                if hasattr(sub, "jaxpr"):
                    best = max(best, biggest(sub.jaxpr.eqns, best))
        return best

    print(f"  max count of full-seq dims in any intermediate: "
          f"{biggest(jaxpr.jaxpr.eqns)} (2 would mean an l×l tensor)")

    print("\n== 4. an assigned architecture, reduced ==")
    cfg = get_config("gemma2-9b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    loss, aux = model.loss(params, {"tokens": toks, "labels": toks})
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"  {cfg.name}: {n / 1e6:.1f}M params, loss {float(loss):.3f} "
          f"(uniform≈{np.log(cfg.vocab_size):.3f})")


if __name__ == "__main__":
    main()
