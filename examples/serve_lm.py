"""Streaming serving demo: concurrent clients over the async front door.

Spawns an :class:`AsyncLMServer` around the request-level EngineCore and a
handful of streaming clients — tokens print as they arrive, per-request
sampling params (temperature / top-k / top-p / seed / stop sequences) ride
each request, and one client cancels mid-stream to show pages being freed
for the survivors.  After the drain it prints each request's lifecycle
span (submitted → admitted → first_token → finished/aborted, with event
offsets) and a snapshot of the engine's metrics registry — the same
counters ``/metrics`` and ``--metrics-json`` expose on the launcher.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch deepseek-7b-smoke]
      PYTHONPATH=src python examples/serve_lm.py --temperature 0.8 \
          --top-k 50 --top-p 0.95 --seed 7 --stop 17,3
      PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b-smoke \
          --slot               # slot-contiguous engine, sync (no streaming)
"""
import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (AsyncLMServer, EngineCore, Request,
                           SamplingParams, ServingEngine,
                           UnsupportedCacheLayout)


def parse_stop(spec):
    """``"5,9;12"`` → ((5, 9), (12,)): ';' splits sequences, ',' tokens."""
    if not spec:
        return ()
    return tuple(tuple(int(t) for t in s.split(",")) for s in spec.split(";"))


async def stream_client(server, req, *, cancel_after=None, t0=0.0):
    """Consume one request's token stream, printing tokens as they land."""
    toks = []
    label = (f"T={req.sampling.temperature}" if req.sampling.temperature > 0
             else "greedy")
    async for tok in server.generate(req):
        toks.append(tok)
        print(f"  [{time.perf_counter() - t0:6.2f}s] req {req.uid:2d} "
              f"({label:7s}) +tok {tok}")
        if cancel_after is not None and len(toks) >= cancel_after:
            print(f"  [{time.perf_counter() - t0:6.2f}s] req {req.uid:2d} "
                  f"CANCELLED by client after {len(toks)} tokens")
            break              # leaving the async-for aborts the request
    return toks


async def serve(engine, reqs, cancel_uid, t0):
    server = AsyncLMServer(engine, max_waiting=16)
    async with server:
        results = await asyncio.gather(*[
            stream_client(server, r, t0=t0,
                          cancel_after=2 if r.uid == cancel_uid else None)
            for r in reqs])
    return server.summary(), results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b-smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--lanes", "--slots", dest="lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7,
                    help="odd-uid requests sample at this temperature "
                         "(even uids stay greedy for contrast)")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed base; request i uses seed+i — rerun "
                         "with the same seed for identical streams")
    ap.add_argument("--stop", default="",
                    help="stop sequences as token ids (',' joins a "
                         "sequence, ';' separates: '5,9;12')")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--slot", action="store_true",
                    help="force the slot-contiguous engine (required for "
                         "SSM-state caches; sync, no streaming server)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        sp = SamplingParams(
            temperature=0.0 if i % 2 == 0 else args.temperature,
            top_k=None if i % 2 == 0 else args.top_k,
            top_p=None if i % 2 == 0 else args.top_p,
            seed=None if i % 2 == 0 else args.seed + i,
            stop=parse_stop(args.stop))
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 24))).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new=int(rng.integers(6, 16)), sampling=sp))

    if args.slot:
        engine = ServingEngine(cfg, params, slots=args.lanes,
                               max_len=args.max_len)
        kind = "slot-contiguous (sync)"
    else:
        try:
            engine = EngineCore(
                cfg, params, lanes=args.lanes, page_size=args.page_size,
                num_pages=args.lanes * -(-args.max_len // args.page_size),
                chunk_size=args.chunk_size, max_len=args.max_len,
                prefix_cache=args.prefix_cache,
                speculative=args.speculative, spec_k=args.spec_k)
            kind = "EngineCore + AsyncLMServer"
        except UnsupportedCacheLayout as e:
            print(f"[{e.layout}] falling back to the slot engine (sync)")
            engine = ServingEngine(cfg, params, slots=args.lanes,
                                   max_len=args.max_len)
            kind = "slot-contiguous (fallback, sync)"

    t0 = time.perf_counter()
    if isinstance(engine, ServingEngine):
        # no abort() on the slot engine → no async server; drain in batch
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        dt = time.perf_counter() - t0
        n = sum(len(r.tokens) for r in done)
        print(f"{cfg.name} [{kind}]: {len(done)} requests / {n} tokens "
              f"in {dt:.2f}s")
        for r in sorted(done, key=lambda r: r.uid):
            print(f"  req {r.uid:2d}: {r.tokens}")
        return

    cancel_uid = args.requests - 1 if args.requests > 1 else None
    print(f"{cfg.name} [{kind}]: {len(reqs)} streaming clients, "
          f"req {cancel_uid} will cancel mid-stream")
    summary, results = asyncio.run(serve(engine, reqs, cancel_uid, t0))
    dt = time.perf_counter() - t0
    print(f"drained in {dt:.2f}s · sustained {summary['req_s']:.2f} req/s · "
          f"TTFT p50 {summary['ttft_ms_p50']:.1f}ms · "
          f"TPOT {summary['tpot_ms']:.2f}ms · "
          f"{summary['cancelled']} cancelled")
    print(f"pool after drain: {engine.pages_in_use} pages in use "
          f"(cancelled pages were freed mid-serve)")

    # Per-request lifecycle spans, straight from the engine's tracer: each
    # event at its offset from the request's own submit.
    print("request spans (ms from submit):")
    for r, toks in zip(reqs, results):
        span = engine.obs.tracer.span(r.uid)
        tag = " (cancelled)" if r.uid == cancel_uid else ""
        if span is None:
            print(f"  req {r.uid:2d}{tag}: no span recorded")
            continue
        tl = " -> ".join(
            f"{e.name}@{(e.t - span.start_t) * 1e3:.1f}"
            for e in span.events)
        print(f"  req {r.uid:2d}{tag} [{span.status}] {tl}")
        print(f"           tokens: {toks}")

    # Final registry snapshot — the same counters /metrics and
    # --metrics-json expose; print the serving-salient ones.
    reg = engine.obs.registry
    print("registry snapshot:")
    for name in ("steps_total", "mixed_steps_total", "step_traces_total",
                 "tokens_generated_total", "requests_finished_total",
                 "requests_aborted_total", "stream_cancelled_total",
                 "pool_pages_in_use_peak", "step_latency_ms"):
        print(f"  {name} = {reg.value(name):g}")
    ttft = engine.obs.h_ttft_ms
    if ttft.count():
        print(f"  request_ttft_ms p50/p99 = "
              f"{ttft.percentile(0.5):.1f} / {ttft.percentile(0.99):.1f}")


if __name__ == "__main__":
    main()
