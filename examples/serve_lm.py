"""End-to-end serving driver (the paper's kind: inference): batched
requests through a continuous-batching engine, mixed prompt lengths and
sampling temperatures, with throughput accounting.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-9b-smoke]
      PYTHONPATH=src python examples/serve_lm.py --arch deepseek-7b-smoke \
          --paged              # block/paged KV cache (docs/architecture.md)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import PagedServingEngine, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV engine (full-length KV layouts only, "
                         "e.g. deepseek-7b-smoke)")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.paged:
        num_pages = args.slots * args.max_len // args.page_size
        engine = PagedServingEngine(cfg, params, slots=args.slots,
                                    page_size=args.page_size,
                                    num_pages=num_pages,
                                    max_len=args.max_len)
    else:
        engine = ServingEngine(cfg, params, slots=args.slots,
                               max_len=args.max_len)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, 24))).astype(np.int32),
            max_new=int(rng.integers(4, 16)),
            temperature=0.0 if i % 2 == 0 else 0.7))

    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"{cfg.name}: served {len(done)} requests / {n_tok} tokens on "
          f"{args.slots} slots in {dt:.2f}s ({n_tok / dt:.1f} tok/s, CPU)")
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"  req {r.uid:2d} ({mode:7s}, prompt {len(r.prompt):2d}): "
              f"{r.tokens}")


if __name__ == "__main__":
    main()
