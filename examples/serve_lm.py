"""End-to-end serving driver (the paper's kind: inference): batched
requests through the request-level EngineCore — continuous batching,
chunked paged prefill and decode mixed in one step batch, mixed prompt
lengths and sampling temperatures, with throughput accounting.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch deepseek-7b-smoke]
      PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b-smoke \
          --slot               # slot-contiguous engine (any cache layout)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EngineCore, Request, ServingEngine,
                           UnsupportedCacheLayout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", "--slots", dest="lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV reuse: requests open with a "
                         "common system prefix, served from the radix cache "
                         "after the first")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-then-verify speculative decoding (n-gram "
                         "prompt lookup, greedy lanes only; greedy output "
                         "is token-identical, just fewer steps)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per lane per step")
    ap.add_argument("--slot", action="store_true",
                    help="force the slot-contiguous engine (required for "
                         "SSM-state caches, e.g. falcon-mamba-7b-smoke)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.slot:
        engine = ServingEngine(cfg, params, slots=args.lanes,
                               max_len=args.max_len)
        kind = "slot-contiguous"
    else:
        try:
            engine = EngineCore(
                cfg, params, lanes=args.lanes, page_size=args.page_size,
                num_pages=args.lanes * -(-args.max_len // args.page_size),
                chunk_size=args.chunk_size, max_len=args.max_len,
                prefix_cache=args.prefix_cache,
                speculative=args.speculative, spec_k=args.spec_k)
            kind = f"EngineCore paged/chunked(c={args.chunk_size})"
            if args.prefix_cache:
                kind += "+prefix-cache"
            if args.speculative:
                kind += f"+spec(k={args.spec_k})"
        except UnsupportedCacheLayout as e:
            # ring/SSM layouts, or a family with no paged chunk step
            # (e.g. encdec) — the slot engine serves both.
            print(f"[{e.layout}] falling back to the slot engine")
            engine = ServingEngine(cfg, params, slots=args.lanes,
                                   max_len=args.max_len)
            kind = "slot-contiguous (fallback)"

    rng = np.random.default_rng(0)
    # With --prefix-cache, every request opens with the same "system prompt"
    # — after the first finishes, later admissions reuse its resident pages.
    shared = (rng.integers(0, cfg.vocab_size,
                           3 * args.page_size).astype(np.int32)
              if args.prefix_cache else np.zeros(0, np.int32))
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 24))).astype(np.int32)
        engine.submit(Request(
            uid=i,
            prompt=np.concatenate([shared, tail]),
            max_new=int(rng.integers(4, 16)),
            temperature=0.0 if i % 2 == 0 else 0.7))

    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"{cfg.name} [{kind}]: served {len(done)} requests / {n_tok} "
          f"tokens on {args.lanes} lanes in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, CPU)")
    stats = getattr(engine, "prefix_stats", {})
    if stats:
        print(f"  prefix cache: {stats['hit_tokens']} of "
              f"{stats['lookup_tokens']} known tokens served from cache "
              f"(hit_rate {stats['hit_rate']:.3f}), "
              f"{stats['cached_pages']} pages resident, "
              f"{stats['cow_copies']} CoW copies")
    spec = getattr(engine, "spec_stats", {})
    if spec:
        print(f"  speculative: {spec['accepted_tokens']} of "
              f"{spec['drafted_tokens']} drafts accepted "
              f"(+{spec['accepted_per_spec_step']:.2f} tok per drafting "
              f"step, {spec['spec_steps']} drafting steps)")
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"  req {r.uid:2d} ({mode:7s}, prompt {len(r.prompt):2d}): "
              f"{r.tokens}")


if __name__ == "__main__":
    main()
