"""End-to-end training driver: a small LM on the deterministic Markov
corpus, with checkpointing, failure injection, and auto-resume — the whole
fault-tolerant runtime in one script.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ...]

The default (~200 steps of a reduced starcoder2) takes a few minutes on CPU
and the loss drops well below the uniform baseline ln(V).
"""
import argparse
import math
import tempfile

from repro.configs import get_config
from repro.data import DataConfig
from repro.runtime import FailureInjector, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b-smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the 'node' twice mid-run to show recovery")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch, corpus="lm")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(steps=args.steps, lr=args.lr, warmup=10,
                           ckpt_dir=ckpt_dir, ckpt_every=25)
        trainer = Trainer(cfg, dcfg, tcfg)
        injector = None
        if args.inject_failure:
            injector = FailureInjector(
                fail_at_steps=(args.steps // 3, 2 * args.steps // 3))
            print(f"will inject failures at steps {injector.fail_at_steps}")
        metrics = trainer.run(injector=injector)

    uniform = math.log(cfg.vocab_size)
    print(f"\n{'step':>6} {'loss':>8} {'lr':>9} {'ms':>7}")
    for m in metrics[:: max(len(metrics) // 15, 1)] + [metrics[-1]]:
        print(f"{m['step']:6d} {m['loss']:8.4f} {m['lr']:9.2e} "
              f"{m['ms']:7.0f}")
    print(f"\nuniform baseline ln(V) = {uniform:.3f}; "
          f"final loss = {metrics[-1]['loss']:.3f}")
    assert metrics[-1]["loss"] < 0.8 * uniform, "did not learn"
    print("training signal confirmed ✓")


if __name__ == "__main__":
    main()
