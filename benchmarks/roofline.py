"""§Roofline table: read the dry-run JSONs and emit per-cell terms.

Columns per (arch × shape × mesh): compute/memory/collective seconds,
dominant term, MODEL_FLOPS/HLO_FLOPS ratio.  The dry-run must have been run
first (``python -m repro.launch.dryrun --all --mesh both``).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Iterator, List, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells(results_dir: str = RESULTS_DIR) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def roofline_rows() -> Iterator[Tuple[str, float, str]]:
    for c in load_cells():
        tag = f"{c['arch']}/{c['shape']}/{c['mesh']}"
        if c.get("status") != "ok":
            yield (f"roofline/{tag}", 0.0, c.get("status", "?"))
            continue
        r = c["roofline"]
        m = c["memory"]
        note = (f"dominant={r['dominant']} "
                f"useful={r['useful_flop_ratio']:.2f} "
                f"peakGiB={m['peak_bytes'] / 2 ** 30:.1f} "
                f"fits={bool(m['fits'])}")
        yield (f"roofline/{tag}/compute_s", r["compute_s"], note)
        yield (f"roofline/{tag}/memory_s", r["memory_s"], "upper bound")
        yield (f"roofline/{tag}/memory_lb_s", r.get("memory_lb_s", 0.0),
               "fused lower bound")
        yield (f"roofline/{tag}/collective_s", r["collective_s"], "")


def markdown_table(results_dir: str = RESULTS_DIR) -> str:
    """The EXPERIMENTS.md §Roofline table."""
    lines = [
        "| arch | shape | mesh | compute s | memory s (ub/lb) | "
        "collective s | dominant | useful FLOP ratio | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(results_dir):
        if c.get("status") != "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"{c.get('status', '?')[:40]} | — | — | — |")
            continue
        r, m = c["roofline"], c["memory"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} / {r.get('memory_lb_s', 0):.3g} "
            f"| {r['collective_s']:.3g} "
            f"| {r['dominant']} "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {m['peak_bytes'] / 2 ** 30:.2f} "
            f"| {'✓' if m['fits'] else '✗'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
