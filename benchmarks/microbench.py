"""Wall-clock microbenchmarks of the JAX/Pallas implementation on this host.

CPU timings are NOT the TPU performance claim (that's §Roofline); they
certify that the code paths run and give relative A/B signals (LUT vs exact
exp, streaming vs naive attention, kernel vs reference).
"""
from __future__ import annotations

import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_lut_exp() -> Iterator[Row]:
    from repro.core.lut_exp import lut_exp
    x = jnp.linspace(-10, 10, 1 << 16)
    f_lut = jax.jit(lambda v: lut_exp(v))
    f_exact = jax.jit(jnp.exp)
    us_l = _timeit(f_lut, x)
    us_e = _timeit(f_exact, x)
    yield ("micro/lut_exp_64k", us_l, f"exact={us_e:.1f}us")
    from repro.kernels import lut_exp as k_lut
    yield ("micro/lut_exp_kernel_64k",
           _timeit(jax.jit(lambda v: k_lut(v)), x), "interpret mode")


def bench_attention() -> Iterator[Row]:
    from repro.core.streaming_attention import (naive_attention,
                                                streaming_attention)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    f_s = jax.jit(lambda a, b, c: streaming_attention(a, b, c, causal=True,
                                                      block_k=128))
    f_n = jax.jit(lambda a, b, c: naive_attention(a, b, c, causal=True))
    yield ("micro/streaming_attn_512", _timeit(f_s, q, k, v), "O(l) memory")
    yield ("micro/naive_attn_512", _timeit(f_n, q, k, v), "O(l^2) memory")


def bench_int8() -> Iterator[Row]:
    from repro.core.quant import int8_matmul, quantize
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1024, 1024)).astype(np.float32))
    wq = quantize(w, axis=0)
    f_q = jax.jit(lambda a: int8_matmul(a, wq))
    f_f = jax.jit(lambda a: a @ w)
    yield ("micro/int8_matmul_256x1024x1024", _timeit(f_q, x), "")
    yield ("micro/f32_matmul_256x1024x1024", _timeit(f_f, x), "")


def bench_train_step() -> Iterator[Row]:
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("deepseek-7b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    step = jax.jit(lambda p, b: jax.grad(
        lambda q: model.loss(q, b)[0])(p))
    yield ("micro/train_grad_smoke_4x64", _timeit(step, params, batch,
                                                  iters=3), "")


def bench_paged_kernel() -> Iterator[Row]:
    """Quick tiled-vs-untiled varlen A/B for the `run.py` table; the full
    sweep (with bytes-moved estimates) lives in :func:`kernel_sweep`."""
    from repro.kernels.autotune import (KernelConfig, KernelGeom,
                                        measure_step_s)
    geom = KernelGeom(hq=4, hkv=2, head_dim=32, page_size=8)
    wl = {"prefill": [(32, 32)] * 4}
    us_1 = measure_step_s(KernelConfig(block_q=1), geom, wl) * 1e6
    us_8 = measure_step_s(KernelConfig(block_q=8), geom, wl) * 1e6
    yield ("micro/varlen_untiled_4x32", us_1, "batch=T dataflow")
    yield ("micro/varlen_tiled_bq8_4x32", us_8, f"untiled={us_1:.1f}us")


ALL_MICRO = (bench_lut_exp, bench_attention, bench_int8, bench_paged_kernel,
             bench_train_step)


# --------------------------------------------------------------------------
# paged-attention kernel sweep → BENCH_kernels.json
# --------------------------------------------------------------------------

def kernel_sweep(*, tiny: bool = False) -> dict:
    """Sweep (tokens-per-lane × Bq × block_pages) over the varlen kernel.

    Each cell pairs a *measured* step time with the roofline's bytes-moved
    estimate for the same shapes, so the JSON records both what the
    hardware did and what the model predicted it would do — the
    tiled-vs-untiled KV-traffic reduction (~Bq× on prefill chunks) is
    checkable from the estimates alone, timing noise aside.  Ends with an
    autotune arm: the roofline-picked config round-tripped through the
    on-disk table and timed against the hardcoded default.
    """
    import tempfile
    from pathlib import Path

    from repro.kernels.autotune import (DEFAULT_CONFIG, KernelConfig,
                                        KernelGeom, measure_step_s,
                                        predict_step_s, resolve_config,
                                        save_config, tune)
    from repro.perfmodel.model import (platform_spec,
                                       varlen_attention_roofline,
                                       varlen_attention_traffic)

    lanes = 2 if tiny else 4
    geom = (KernelGeom(hq=2, hkv=1, head_dim=16, page_size=4) if tiny
            else KernelGeom(hq=8, hkv=2, head_dim=64, page_size=16))
    tokens_per_lane = (1, 8) if tiny else (1, 8, 32)
    bqs = (1, 4, 8) if tiny else (1, 4, 8, 16)
    bps = (1, 2) if tiny else (1, 4)
    spec = platform_spec(jax.default_backend())

    rows = []
    for tpl in tokens_per_lane:
        segments = [(tpl, 2 * tpl + geom.page_size)] * lanes
        wl = {"arm": segments}
        for bq in bqs:
            for bp in bps:
                cfg = KernelConfig(block_q=bq, block_pages=bp)
                traffic = varlen_attention_traffic(
                    segments, block_q=bq, block_pages=bp,
                    page_size=geom.page_size, hq=geom.hq, hkv=geom.hkv,
                    head_dim=geom.head_dim)
                rows.append({
                    "tokens_per_lane": tpl, "block_q": bq, "block_pages": bp,
                    "measured_us": measure_step_s(cfg, geom, wl) * 1e6,
                    "predicted_us": varlen_attention_roofline(
                        spec, traffic, block_pages=bp) * 1e6,
                    "bytes_kv": traffic["bytes_kv"],
                    "pages_read": traffic["pages_read"],
                    "grid_steps": traffic["grid_steps"],
                })

    # Autotune arm: tune → save → load → same dispatch, then time tuned vs
    # the hardcoded default on the mixed workload the tuner optimises for.
    wl_mix = {"mixed": [(t, 2 * t + geom.page_size) for t in
                        ([8, 1] if tiny else [32, 32, 1, 1])]}
    tuned, report = tune(geom, workloads=wl_mix)
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "autotune.json"
        save_config("microbench", jax.default_backend(), tuned, path=path)
        loaded = resolve_config("microbench", jax.default_backend(),
                                path=path)
    roundtrip_ok = (loaded.block_q == tuned.block_q
                    and loaded.block_pages == tuned.block_pages
                    and loaded.dequant == tuned.dequant)
    default_us = measure_step_s(DEFAULT_CONFIG, geom, wl_mix) * 1e6
    tuned_us = measure_step_s(loaded, geom, wl_mix) * 1e6
    # Predicted times are the deterministic half of the A/B: CI gates on
    # them (tuned ≤ default by construction — the sweep covers the
    # incumbent); measured wall-times are recorded for trends only.
    pred_default_us = predict_step_s(DEFAULT_CONFIG, geom, wl_mix,
                                     spec) * 1e6
    pred_tuned_us = predict_step_s(loaded, geom, wl_mix, spec) * 1e6
    return {
        "platform": jax.default_backend(),
        "tiny": tiny,
        "geom": {"hq": geom.hq, "hkv": geom.hkv, "head_dim": geom.head_dim,
                 "page_size": geom.page_size, "lanes": lanes},
        "sweep": rows,
        "autotune": {
            "default": {**DEFAULT_CONFIG.describe(),
                        "measured_us": default_us,
                        "predicted_us": pred_default_us},
            "tuned": {**loaded.describe(), "measured_us": tuned_us,
                      "predicted_us": pred_tuned_us},
            "roundtrip_ok": roundtrip_ok,
            "candidates_scored": len(report),
        },
    }


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI scale: tiny shapes, reduced sweep axes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the kernel sweep to PATH "
                         "(e.g. BENCH_kernels.json)")
    ap.add_argument("--skip-micro", action="store_true",
                    help="only run the kernel sweep")
    args = ap.parse_args(argv)

    if not args.skip_micro:
        for micro in ALL_MICRO:
            for name, us, note in micro():
                print(f"{name:40s} {us:10.1f} us   {note}")
    result = kernel_sweep(tiny=args.tiny)
    at = result["autotune"]
    print(f"kernel sweep: {len(result['sweep'])} cells on "
          f"{result['platform']}; tuned {at['tuned']['measured_us']:.1f}us "
          f"vs default {at['default']['measured_us']:.1f}us "
          f"(roundtrip_ok={at['roundtrip_ok']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
