"""Wall-clock microbenchmarks of the JAX/Pallas implementation on this host.

CPU timings are NOT the TPU performance claim (that's §Roofline); they
certify that the code paths run and give relative A/B signals (LUT vs exact
exp, streaming vs naive attention, kernel vs reference).
"""
from __future__ import annotations

import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_lut_exp() -> Iterator[Row]:
    from repro.core.lut_exp import lut_exp
    x = jnp.linspace(-10, 10, 1 << 16)
    f_lut = jax.jit(lambda v: lut_exp(v))
    f_exact = jax.jit(jnp.exp)
    us_l = _timeit(f_lut, x)
    us_e = _timeit(f_exact, x)
    yield ("micro/lut_exp_64k", us_l, f"exact={us_e:.1f}us")
    from repro.kernels import lut_exp as k_lut
    yield ("micro/lut_exp_kernel_64k",
           _timeit(jax.jit(lambda v: k_lut(v)), x), "interpret mode")


def bench_attention() -> Iterator[Row]:
    from repro.core.streaming_attention import (naive_attention,
                                                streaming_attention)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    f_s = jax.jit(lambda a, b, c: streaming_attention(a, b, c, causal=True,
                                                      block_k=128))
    f_n = jax.jit(lambda a, b, c: naive_attention(a, b, c, causal=True))
    yield ("micro/streaming_attn_512", _timeit(f_s, q, k, v), "O(l) memory")
    yield ("micro/naive_attn_512", _timeit(f_n, q, k, v), "O(l^2) memory")


def bench_int8() -> Iterator[Row]:
    from repro.core.quant import int8_matmul, quantize
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1024, 1024)).astype(np.float32))
    wq = quantize(w, axis=0)
    f_q = jax.jit(lambda a: int8_matmul(a, wq))
    f_f = jax.jit(lambda a: a @ w)
    yield ("micro/int8_matmul_256x1024x1024", _timeit(f_q, x), "")
    yield ("micro/f32_matmul_256x1024x1024", _timeit(f_f, x), "")


def bench_train_step() -> Iterator[Row]:
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("deepseek-7b-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    step = jax.jit(lambda p, b: jax.grad(
        lambda q: model.loss(q, b)[0])(p))
    yield ("micro/train_grad_smoke_4x64", _timeit(step, params, batch,
                                                  iters=3), "")


ALL_MICRO = (bench_lut_exp, bench_attention, bench_int8, bench_train_step)
