"""Serving benchmarks: slot vs paged engines + the in-place decode A/B.

Three families, all emitted as CSV rows (``benchmarks.run``) *and* as a
machine-readable ``BENCH_serving.json`` so the perf trajectory is tracked
across PRs:

1. **Engine throughput** — slot-contiguous vs paged KV at the SAME
   resident-KV budget under mixed traffic (a couple of long prompts among
   many short ones).  The slot engine sizes every lane for the longest
   request; the paged engine spends rows page-by-page, so the same budget
   sustains more concurrent lanes.  Per-step decode latency (p50/p95) and
   peak resident cache rows are recorded per engine.

2. **Step breakdown** — the PR-1 gather path vs the in-place paged path at
   equal row budget, one attention layer, same pool/table/occupancy:

   - legacy: gather the contiguous (B, Hkv, W·ps, D) view from the page
     table, attend over it per lane, write the active page back — the
     per-step O(B·H·L·D) copy the in-place kernel deleted;
   - in-place: write each lane's one new KV row at its (page, offset) and
     attend through the table (``kernels/paged_attention``) — no copy.

   Component timings (gather / attend / write-back) show where the legacy
   milliseconds went and that the live step is attend-dominated.

CPU numbers are relative A/B signals, not TPU claims (docs/benchmarks.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]

_JSON_DEFAULT = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


# --------------------------------------------------------------- utilities --

def _time_ms(fn, *args, iters: int = 10) -> float:
    """Best-of-N wall-clock ms of ``fn(*args)`` after a compile warm-up
    (min, not median: these shapes run multi-threaded and the best sample
    is the least contended one)."""
    jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e3)
    return float(np.min(samples))


def _time_state_ms(fn, state, iters: int = 10) -> Tuple[float, Any]:
    """Best-of-N ms of a donating state → state step, chained like a real
    decode loop (donation keeps pool updates in place where the backend
    supports aliasing; XLA:CPU copies regardless — both write paths pay
    that copy equally, see the JSON note)."""
    state = fn(*state)                      # compile + warm
    jax.block_until_ready(state)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state = fn(*state)
        jax.block_until_ready(state)
        samples.append((time.perf_counter() - t0) * 1e3)
    return float(np.min(samples)), state


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


# ------------------------------------------------------- engine throughput --

def _mixed_requests(vocab: int, tiny: bool, seed: int = 7):
    """Many short requests + two long-prompt ones.

    The long prompts (not long generations) force the slot engine's
    ``max_len`` up — every lane reserves the worst case so such requests can
    land anywhere — while the paged engine spends only the pages the long
    sequence actually needs, only while it is resident.
    """
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    if tiny:
        prompts: List[int] = [4 + (i % 3) * 2 for i in range(10)] + [48]
    else:
        prompts = [4 + (i % 3) * 2 for i in range(48)] + [384, 384]
    return [Request(uid=i, prompt=rng.integers(0, vocab, lp
                                               ).astype(np.int32), max_new=8)
            for i, lp in enumerate(prompts)]


def _instrumented_drain(engine, requests, rows_in_use) -> Dict[str, Any]:
    """Drain traffic, timing every decode step and tracking cache pressure."""
    for r in requests:
        engine.submit(r)
    lat: List[float] = []
    peak_rows = 0
    steps = 0
    t0 = time.perf_counter()
    while engine.queue or any(a is not None for a in engine.active):
        s0 = time.perf_counter()
        engine.step()
        lat.append((time.perf_counter() - s0) * 1e3)
        peak_rows = max(peak_rows, rows_in_use(engine))
        steps += 1
        if steps > 10_000:
            raise RuntimeError("serving did not drain")
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in engine.finished)
    engine.finished.clear()             # engine is reused across passes
    return {"tok_s": toks / dt, "tokens": toks, "steps": steps,
            "step_ms_p50": _pct(lat, 50), "step_ms_p95": _pct(lat, 95),
            "peak_cache_rows": int(peak_rows)}


def _engine_results(tiny: bool) -> Dict[str, Any]:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedServingEngine, ServingEngine

    page = 8 if tiny else 16
    max_len = 128 if tiny else 1024          # serving SLA: longest request
    budget_rows = (2 if tiny else 4) * max_len    # resident-KV budget
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    slot_lanes = budget_rows // max_len
    paged_lanes = 4 if tiny else 16          # page pool spreads wider
    num_pages = budget_rows // page

    # Engines are REUSED across passes: early passes warm the jit caches
    # (per-width decode buckets, per-length prefill buckets), the last pass
    # is the steady state a long-running server actually sees.
    slot_eng = ServingEngine(cfg, params, slots=slot_lanes, max_len=max_len)
    paged_eng = PagedServingEngine(cfg, params, slots=paged_lanes,
                                   page_size=page, num_pages=num_pages,
                                   max_len=max_len)
    for _ in range(2 if tiny else 3):
        slot = _instrumented_drain(
            slot_eng, _mixed_requests(cfg.vocab_size, tiny),
            lambda e: e.slots * e.max_len)
        paged = _instrumented_drain(
            paged_eng, _mixed_requests(cfg.vocab_size, tiny),
            lambda e: e.pages_in_use * e.kv.page_size)

    slot["lanes"], paged["lanes"] = slot_lanes, paged_lanes
    return {"budget_rows": budget_rows, "page_size": page,
            "num_pages": num_pages, "max_len": max_len,
            "slot": slot, "paged": paged,
            "speedup": paged["tok_s"] / slot["tok_s"]}


# --------------------------------------------------------- step breakdown --

def _breakdown_results(tiny: bool) -> Dict[str, Any]:
    """Gather-path vs in-place decode step at equal row budget (1 layer)."""
    from repro.core.streaming_attention import naive_attention
    from repro.kernels.paged_attention import paged_attention

    if tiny:
        b, hq, hkv, d, ps, w = 2, 4, 2, 32, 8, 4
    else:
        # Memory-bound regime (the serving-relevant one): the gathered
        # (B, Hkv, W·ps, D) views are ~17 MB per pool — far beyond cache —
        # so the legacy copy costs real bandwidth every step.
        b, hq, hkv, d, ps, w = 32, 8, 2, 128, 16, 64
    n = b * w + 1                            # every lane fully grown
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)), jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)), jnp.bfloat16)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    newk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.bfloat16)
    newv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.bfloat16)
    tbl = jnp.asarray(
        np.stack([rng.permutation(n - 1)[:w] for _ in range(b)]), jnp.int32)
    idxs = jnp.asarray(rng.integers(ps * (w - 1), ps * w, size=b), jnp.int32)

    def gather(pool):
        out = jnp.moveaxis(jnp.take(pool, tbl, axis=0), 1, 2)
        s = out.shape
        return out.reshape(s[0], s[1], s[2] * s[3], *s[4:])

    def writeback_page(pool, view):          # one whole page per lane
        page_no = idxs // ps
        page_ids = jnp.take_along_axis(tbl, page_no[:, None], 1)[:, 0]
        rows = page_no[:, None] * ps + jnp.arange(ps)[None, :]
        page = jnp.take_along_axis(
            view, rows[:, None, :, None], axis=2).astype(pool.dtype)
        return pool.at[page_ids].set(jnp.moveaxis(page, 1, 2)
                                     .reshape(b, ps, hkv, d)
                                     .transpose(0, 2, 1, 3))

    def write_row(kp, vp):                   # one row per lane
        page_ids = jnp.take_along_axis(tbl, (idxs // ps)[:, None], 1)[:, 0]
        off = idxs % ps
        return (kp.at[page_ids, :, off].set(newk.astype(kp.dtype)),
                vp.at[page_ids, :, off].set(newv.astype(vp.dtype)))

    def attend_view(kg, vg):                 # per-lane view attention (PR 1)
        return jax.vmap(
            lambda qb, kb, vb, i: naive_attention(
                qb[None], kb[None], vb[None], causal=True,
                q_offset=i, kv_len=i + 1)[0])(q, kg, vg, idxs)

    # Attention paths, each jitted whole so XLA fuses what it can — the
    # legacy arm is PR 1's real dataflow (gather feeding the view attend).
    legacy_gather = jax.jit(lambda kp, vp: (gather(kp), gather(vp)))
    legacy_attend_path = jax.jit(
        lambda kp, vp: attend_view(gather(kp), gather(vp)))
    inplace_attend_path = jax.jit(
        lambda kp, vp: paged_attention(q, kp, vp, tbl, idxs + 1))

    # Pool writers: donated + chained like the engine's decode loop.  The
    # legacy arm writes BOTH pools' active page (PR 1's scatter_active_page
    # covered every cache leaf), matching the in-place arm's k+v row writes.
    j_writeback = jax.jit(
        lambda kp, vp, kg, vg: (writeback_page(kp, kg),
                                writeback_page(vp, vg)),
        donate_argnums=(0, 1))
    j_write_row = jax.jit(write_row, donate_argnums=(0, 1))

    kg, vg = legacy_gather(kp, vp)
    iters = 5 if tiny else 30
    out = {
        "shape": {"lanes": b, "heads_q": hq, "heads_kv": hkv, "d_head": d,
                  "page_size": ps, "pages_per_lane": w,
                  "rows_per_lane": ps * w},
        "note": "write paths both pay a full pool copy on XLA:CPU (no "
                "scatter aliasing there even under donation); on TPU the "
                "row write is strictly less traffic than the page "
                "write-back.  The attend path is the PR's hot-path delta.",
        # pure reads first — the donating chain below consumes the pools
        "legacy_gather_ms": _time_ms(legacy_gather, kp, vp, iters=iters),
        "legacy_attend_path_ms": _time_ms(legacy_attend_path, kp, vp,
                                          iters=iters),
        "attend_in_place_ms": _time_ms(inplace_attend_path, kp, vp,
                                       iters=iters),
    }
    wb_ms, (kp, vp) = _time_state_ms(
        lambda kp_, vp_: j_writeback(kp_, vp_, kg, vg), (kp, vp),
        iters=iters)
    row_ms, _ = _time_state_ms(j_write_row, (kp, vp), iters=iters)
    out.update(
        legacy_writeback_page_ms=wb_ms, write_row_ms=row_ms,
        attend_speedup=out["legacy_attend_path_ms"]
        / out["attend_in_place_ms"],
        step_speedup=(out["legacy_attend_path_ms"] + wb_ms)
        / (out["attend_in_place_ms"] + row_ms))
    return out


# ----------------------------------------------------------------- driver --

def run_serving(tiny: bool = False) -> Dict[str, Any]:
    return {"meta": {"platform": jax.default_backend(), "tiny": tiny,
                     "config": "deepseek-7b-smoke"},
            "engines": _engine_results(tiny),
            "step_breakdown": _breakdown_results(tiny)}


def write_json(results: Dict[str, Any], path: str = _JSON_DEFAULT) -> None:
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")


def rows_from(results: Dict[str, Any]) -> Iterator[Row]:
    e, bd = results["engines"], results["step_breakdown"]
    yield ("serving/slot_contiguous_tok_s", e["slot"]["tok_s"],
           f"{e['slot']['tokens']} toks; {e['slot']['lanes']} lanes x "
           f"{e['max_len']} rows = budget")
    yield ("serving/paged_tok_s", e["paged"]["tok_s"],
           f"same budget as {e['num_pages']} x {e['page_size']}-row pages; "
           f"{e['paged']['lanes']} lanes")
    yield ("serving/paged_speedup", e["speedup"],
           "equal-memory mixed-length traffic; >1 means paging pays")
    yield ("serving/paged_step_ms_p50", e["paged"]["step_ms_p50"],
           "decode step latency, in-place paged path")
    yield ("serving/paged_peak_cache_rows", float(e["paged"]["peak_cache_rows"]),
           f"resident rows at peak (slot engine: "
           f"{e['slot']['peak_cache_rows']} always)")
    yield ("serving/step_legacy_gather_ms", bd["legacy_gather_ms"],
           "the per-step copy the in-place kernel deleted")
    yield ("serving/step_attend_in_place_ms", bd["attend_in_place_ms"],
           "paged attention through the table (live step, dominant)")
    yield ("serving/step_write_row_ms", bd["write_row_ms"],
           "single-row pool write (live step)")
    yield ("serving/attend_speedup_vs_gather_path", bd["attend_speedup"],
           f"legacy gather+attend {bd['legacy_attend_path_ms']:.3g} ms -> "
           f"in-place {bd['attend_in_place_ms']:.3g} ms at "
           f"{bd['shape']['rows_per_lane']} rows/lane")
    yield ("serving/step_speedup_vs_gather_path", bd["step_speedup"],
           "attend+write vs PR 1 gather+attend+page-writeback")


def bench_paged_serving() -> Iterator[Row]:
    results = run_serving()
    write_json(results)                 # benchmarks.run refreshes the JSON
    yield from rows_from(results)


ALL_SERVING = (bench_paged_serving,)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serving benchmarks -> CSV rows + BENCH_serving.json")
    ap.add_argument("--json", default=_JSON_DEFAULT,
                    help="output path for the JSON results")
    ap.add_argument("--tiny", action="store_true",
                    help="CI scale: small pools/traffic, crash-test numbers")
    args = ap.parse_args()
    results = run_serving(tiny=args.tiny)
    write_json(results, args.json)
    print("name,value,derived")
    for name, value, note in rows_from(results):
        print(f"{name},{value:.6g},{note}")
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
