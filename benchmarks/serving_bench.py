"""Serving benchmarks: engines, decode A/B, prefill TTFT, prefix reuse.

Eight families, all emitted as CSV rows (``benchmarks.run``) *and* as a
machine-readable ``BENCH_serving.json`` so the perf trajectory is tracked
across PRs.  Every EngineCore aggregate — step latency percentiles,
mixed-step counts, prefix hit rates, speculative acceptance, engine and
server TTFT/TPOT — is read back from the engine's own metrics registry
(``repro.serving.metrics``) via snapshot/delta windows; the bench
re-derives nothing the serving stack already counts.

1. **Engine throughput** — slot-contiguous vs the request-level
   ``EngineCore`` in BOTH packings (the PR-3 padded ``(lanes, C)`` block
   and the token-level ragged stream) at the SAME resident-KV budget under
   mixed traffic (a couple of long prompts among many short ones).  The
   slot engine sizes every lane for the longest request; the paged engines
   spend rows page-by-page, so the same budget sustains more concurrent
   lanes — and the ragged arm additionally never pays the mixed-batch
   padding tax (a decode lane costs 1 token-row, not a chunk-wide one).
   Per-step decode latency (p50/p95), peak resident cache rows, mixed
   chunked-prefill+decode step counts and ``padding_efficiency`` (live
   token rows / computed token rows) are recorded; each arm carries its
   ``prefill_mode`` ("contiguous" / "chunked") and ``packing``.

2. **Step breakdown** — the PR-1 gather path vs the in-place paged path at
   equal row budget, one attention layer, same pool/table/occupancy:

   - legacy: gather the contiguous (B, Hkv, W·ps, D) view from the page
     table, attend over it per lane, write the active page back — the
     per-step O(B·H·L·D) copy the in-place kernel deleted;
   - in-place: write each lane's one new KV row at its (page, offset) and
     attend through the table (``kernels/paged_attention``) — no copy.

3. **Prefill TTFT** — time-to-first-token on long prompts, chunked paged
   prefill (``EngineCore``: fixed-shape chunks streamed straight into
   pages) vs the PR-2 *scatter* path (b=1 contiguous prefill jitted per
   prompt length, then scattered into pages — reconstructed here inline as
   the baseline), at equal page budget.  Measured over a stream of
   *distinct* prompt lengths — the serving-realistic case, where the
   scatter path pays a fresh XLA compile per length while chunking's
   static shapes stay warm — and once more at a repeated (warm) length.
   Each arm is tagged ``prefill_mode: chunked|scatter``.

4. **Speculative decoding** — draft-then-verify A/B, spec engine (n-gram
   proposer, k=4) vs an identical non-spec engine, three arms.
   *Repetitive*: N identical greedy requests — once the first stream
   finishes, the proposer's history replays it and the verify accepts
   nearly every draft, so each drafting step commits several tokens
   (nightly CI asserts ``accepted_per_spec_step > 1.5``).
   *Adversarial*: lookup-hostile traffic — the proposer issues no drafts
   and speculation must not cost throughput (CI asserts the spec/non-spec
   tok/s ratio ≥ 0.8).  *Rejection*: a maximally wrong proposer — every
   draft verified and rolled back, the worst-case cost bound (recorded,
   no floor).  Acceptance rate, accepted-tokens-per-drafting-step and
   tok/s are recorded per arm; every arm drains until a pass compiles
   nothing new, so the reported numbers are a warm server's.

5. **Prefix reuse** — the shared-system-prompt workload: N requests open
   with the same page-aligned prefix and differ only in their tails.  The
   first request prefills cold and publishes its full pages into the radix
   prefix cache; every later admission is granted those resident pages and
   streams only its tail.  Measured at equal memory on one engine: cold
   TTFT (the first shared-prefix request, compile-warm) vs warm TTFT (the
   rest), with exact `prefix_hit_rate` (hit tokens / known tokens over the
   warm phase — deterministic, not a timing), `pages_shared` grants and
   CoW-copy counts from the cache's own telemetry.  The nightly CI job
   asserts `prefix_hit_rate ≥ 0.9` and warm-over-cold TTFT speedup > 1.

6. **Serve loop** — the async front door (PR 8) vs the batch driver on
   the SAME warm engine.  The batch arm submits everything at t=0 and
   steps to drain — its TTFT tail is the admission queue.  The stream arm
   replays the same traffic through :class:`AsyncLMServer` under Poisson
   arrivals whose rate is *self-calibrated* to 70% of what the batch arm
   just sustained (the classic sustained-utilization point — offering
   100% is a knife edge where backlog, not the server, sets TTFT),
   measuring per-client TTFT/TPOT from each request's own arrival.  Nightly CI asserts the
   streaming TTFT p50 ≤ the batch driver's (spreading arrivals over the
   window the engine needs anyway must not cost first-token latency).

7. **Observability overhead** — metrics-on vs metrics-off engines on
   identical mixed traffic.  The registry/tracing layer is host-side
   python on the step boundary, so it must cost ~nothing next to a
   jitted step; nightly CI asserts the on/off tok/s ratio ≥ 0.98.  The
   serve-loop family additionally arms the **retrace sentinel**
   (``mark_warm`` + one measured pass per arm) and records
   ``retraces_after_warm`` — nightly CI pins it at 0, so a mid-traffic
   jit recompile (the PR 8 table-width-shrink class of bug) fails the
   build instead of silently costing a ~2 s stall.

8. **Sharded serving** — the tensor-parallel engine (PR 9): identical
   mixed traffic served at mesh 1 vs mesh 2, tok/s plus the analytic
   per-token / per-step all-gather bytes at each width.  The backend pins
   its device count at first jax init (1 on CPU), so this arm runs in a
   subprocess with two forced host devices, exactly like
   ``tests/_multidevice.py``.  The tok/s ratio is recorded with **no CPU
   floor**: two placeholder devices share the same cores, so the CPU
   number measures shard_map + collective overhead, not the speedup a
   real 2-chip mesh sees (the collective-bytes column is the
   device-independent signal).

CPU numbers are relative A/B signals, not TPU claims (docs/benchmarks.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]

_JSON_DEFAULT = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


# --------------------------------------------------------------- utilities --

def _time_ms(fn, *args, iters: int = 10) -> float:
    """Best-of-N wall-clock ms of ``fn(*args)`` after a compile warm-up
    (min, not median: these shapes run multi-threaded and the best sample
    is the least contended one)."""
    jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e3)
    return float(np.min(samples))


def _time_state_ms(fn, state, iters: int = 10) -> Tuple[float, Any]:
    """Best-of-N ms of a donating state → state step, chained like a real
    decode loop (donation keeps pool updates in place where the backend
    supports aliasing; XLA:CPU copies regardless — both write paths pay
    that copy equally, see the JSON note)."""
    state = fn(*state)                      # compile + warm
    jax.block_until_ready(state)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state = fn(*state)
        jax.block_until_ready(state)
        samples.append((time.perf_counter() - t0) * 1e3)
    return float(np.min(samples)), state


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


# ------------------------------------------------------- engine throughput --

def _mixed_requests(vocab: int, tiny: bool, seed: int = 7):
    """Many short requests + two long-prompt ones.

    The long prompts (not long generations) force the slot engine's
    ``max_len`` up — every lane reserves the worst case so such requests can
    land anywhere — while the paged engine spends only the pages the long
    sequence actually needs, only while it is resident.
    """
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    if tiny:
        prompts: List[int] = [4 + (i % 3) * 2 for i in range(10)] + [48]
    else:
        prompts = [4 + (i % 3) * 2 for i in range(48)] + [384, 384]
    return [Request(uid=i, prompt=rng.integers(0, vocab, lp
                                               ).astype(np.int32), max_new=8)
            for i, lp in enumerate(prompts)]


def _instrumented_drain(engine, requests, rows_in_use,
                        core: bool = False) -> Dict[str, Any]:
    """Drain traffic and report per-pass aggregates.

    ``core=True``: the engine is an EngineCore and every aggregate —
    step-latency percentiles, mixed-step counts, live/padded rows, peak
    pool pages — is read back from the engine's own metrics registry
    (``snapshot()``/``delta()`` windows over the lifetime counters plus a
    count-offset window over the ``step_latency_ms`` histogram), not
    recomputed bench-side.  ``rows_in_use`` is only sampled for the slot
    engine, which carries no registry."""
    for r in requests:
        engine.submit(r)
    if core:
        obs = engine.obs
        snap = obs.registry.snapshot()
        step_n0 = obs.h_step_ms.count()
        obs.reset_peaks()
    lat: List[float] = []
    peak_rows = 0
    steps = 0

    def busy():
        if core:
            return engine.scheduler.has_work()
        return engine.queue or any(a is not None for a in engine.active)

    t0 = time.perf_counter()
    while busy():
        if core:
            engine.step()
        else:
            s0 = time.perf_counter()
            engine.step()
            lat.append((time.perf_counter() - s0) * 1e3)
            peak_rows = max(peak_rows, rows_in_use(engine))
        steps += 1
        if steps > 10_000:
            raise RuntimeError("serving did not drain")
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in engine.finished)
    engine.finished.clear()             # engine is reused across passes
    if not core:
        return {"tok_s": toks / dt, "tokens": toks, "steps": steps,
                "step_ms_p50": _pct(lat, 50), "step_ms_p95": _pct(lat, 95),
                "peak_cache_rows": int(peak_rows)}
    d = obs.registry.delta(snap)
    live, padded = int(d["live_rows_total"]), int(d["padded_rows_total"])
    return {"tok_s": toks / dt, "tokens": toks,
            "steps": int(d["steps_total"]),
            "step_ms_p50": obs.h_step_ms.percentile(0.50, skip=step_n0),
            "step_ms_p95": obs.h_step_ms.percentile(0.95, skip=step_n0),
            "peak_cache_rows":
                int(obs.g_pool_peak.value() * engine.kv.page_size),
            "mixed_steps": int(d["mixed_steps_total"]),
            "prefill_tokens": int(d["prefill_tokens_total"]),
            "decode_tokens": int(d["decode_tokens_total"]),
            "live_rows": live, "padded_rows": padded,
            "padding_efficiency": live / max(padded, 1)}


def _engine_results(tiny: bool) -> Dict[str, Any]:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineCore, ServingEngine

    page = 8 if tiny else 16
    max_len = 128 if tiny else 1024          # serving SLA: longest request
    budget_rows = (2 if tiny else 4) * max_len    # resident-KV budget
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    slot_lanes = budget_rows // max_len
    paged_lanes = 4 if tiny else 16          # page pool spreads wider
    num_pages = budget_rows // page

    # Engines are REUSED across passes: early passes warm the jit caches
    # (per-width step buckets — and, for the slot engine, per-length prefill
    # buckets), the last pass is the steady state a long-running server
    # actually sees.
    slot_eng = ServingEngine(cfg, params, slots=slot_lanes, max_len=max_len)
    pad_eng = EngineCore(cfg, params, lanes=paged_lanes, page_size=page,
                         num_pages=num_pages, max_len=max_len,
                         chunk_size=2 * page, mode="padded")
    rag_eng = EngineCore(cfg, params, lanes=paged_lanes, page_size=page,
                         num_pages=num_pages, max_len=max_len,
                         chunk_size=2 * page, mode="ragged")
    for _ in range(2 if tiny else 3):
        slot = _instrumented_drain(
            slot_eng, _mixed_requests(cfg.vocab_size, tiny),
            lambda e: e.slots * e.max_len)
        padded = _instrumented_drain(
            pad_eng, _mixed_requests(cfg.vocab_size, tiny),
            lambda e: e.pages_in_use * e.kv.page_size, core=True)
        ragged = _instrumented_drain(
            rag_eng, _mixed_requests(cfg.vocab_size, tiny),
            lambda e: e.pages_in_use * e.kv.page_size, core=True)

    slot["lanes"] = slot_lanes
    padded["lanes"] = ragged["lanes"] = paged_lanes
    slot["prefill_mode"] = "contiguous"
    padded["prefill_mode"] = ragged["prefill_mode"] = "chunked"
    slot["packing"], padded["packing"] = "slots", "padded"
    ragged["packing"] = "ragged"
    # Resolved varlen-kernel block shapes (block_q / block_pages / source:
    # tuned|default) — recorded so a bench regression is attributable to
    # the kernel config that produced the number, not just the packing.
    ragged["kernel_config"] = rag_eng.kernel_config.describe()
    return {"budget_rows": budget_rows, "page_size": page,
            "num_pages": num_pages, "max_len": max_len,
            "token_buckets": list(rag_eng.scheduler.token_buckets),
            "slot": slot, "padded": padded, "ragged": ragged,
            "speedup": ragged["tok_s"] / slot["tok_s"],
            "speedup_padded": padded["tok_s"] / slot["tok_s"],
            "speedup_ragged_vs_padded": ragged["tok_s"] / padded["tok_s"]}


# --------------------------------------------------------- step breakdown --

def _breakdown_results(tiny: bool) -> Dict[str, Any]:
    """Gather-path vs in-place decode step at equal row budget (1 layer)."""
    from repro.core.streaming_attention import naive_attention
    from repro.kernels.paged_attention import paged_attention

    if tiny:
        b, hq, hkv, d, ps, w = 2, 4, 2, 32, 8, 4
    else:
        # Memory-bound regime (the serving-relevant one): the gathered
        # (B, Hkv, W·ps, D) views are ~17 MB per pool — far beyond cache —
        # so the legacy copy costs real bandwidth every step.
        b, hq, hkv, d, ps, w = 32, 8, 2, 128, 16, 64
    n = b * w + 1                            # every lane fully grown
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)), jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)), jnp.bfloat16)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    newk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.bfloat16)
    newv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.bfloat16)
    tbl = jnp.asarray(
        np.stack([rng.permutation(n - 1)[:w] for _ in range(b)]), jnp.int32)
    idxs = jnp.asarray(rng.integers(ps * (w - 1), ps * w, size=b), jnp.int32)

    def gather(pool):
        out = jnp.moveaxis(jnp.take(pool, tbl, axis=0), 1, 2)
        s = out.shape
        return out.reshape(s[0], s[1], s[2] * s[3], *s[4:])

    def writeback_page(pool, view):          # one whole page per lane
        page_no = idxs // ps
        page_ids = jnp.take_along_axis(tbl, page_no[:, None], 1)[:, 0]
        rows = page_no[:, None] * ps + jnp.arange(ps)[None, :]
        page = jnp.take_along_axis(
            view, rows[:, None, :, None], axis=2).astype(pool.dtype)
        return pool.at[page_ids].set(jnp.moveaxis(page, 1, 2)
                                     .reshape(b, ps, hkv, d)
                                     .transpose(0, 2, 1, 3))

    def write_row(kp, vp):                   # one row per lane
        page_ids = jnp.take_along_axis(tbl, (idxs // ps)[:, None], 1)[:, 0]
        off = idxs % ps
        return (kp.at[page_ids, :, off].set(newk.astype(kp.dtype)),
                vp.at[page_ids, :, off].set(newv.astype(vp.dtype)))

    def attend_view(kg, vg):                 # per-lane view attention (PR 1)
        return jax.vmap(
            lambda qb, kb, vb, i: naive_attention(
                qb[None], kb[None], vb[None], causal=True,
                q_offset=i, kv_len=i + 1)[0])(q, kg, vg, idxs)

    # Attention paths, each jitted whole so XLA fuses what it can — the
    # legacy arm is PR 1's real dataflow (gather feeding the view attend).
    legacy_gather = jax.jit(lambda kp, vp: (gather(kp), gather(vp)))
    legacy_attend_path = jax.jit(
        lambda kp, vp: attend_view(gather(kp), gather(vp)))
    inplace_attend_path = jax.jit(
        lambda kp, vp: paged_attention(q, kp, vp, tbl, idxs + 1))

    # Pool writers: donated + chained like the engine's decode loop.  The
    # legacy arm writes BOTH pools' active page (PR 1's scatter_active_page
    # covered every cache leaf), matching the in-place arm's k+v row writes.
    j_writeback = jax.jit(
        lambda kp, vp, kg, vg: (writeback_page(kp, kg),
                                writeback_page(vp, vg)),
        donate_argnums=(0, 1))
    j_write_row = jax.jit(write_row, donate_argnums=(0, 1))

    kg, vg = legacy_gather(kp, vp)
    iters = 5 if tiny else 30
    out = {
        "shape": {"lanes": b, "heads_q": hq, "heads_kv": hkv, "d_head": d,
                  "page_size": ps, "pages_per_lane": w,
                  "rows_per_lane": ps * w},
        "note": "write paths both pay a full pool copy on XLA:CPU (no "
                "scatter aliasing there even under donation); on TPU the "
                "row write is strictly less traffic than the page "
                "write-back.  The attend path is the PR's hot-path delta.",
        # pure reads first — the donating chain below consumes the pools
        "legacy_gather_ms": _time_ms(legacy_gather, kp, vp, iters=iters),
        "legacy_attend_path_ms": _time_ms(legacy_attend_path, kp, vp,
                                          iters=iters),
        "attend_in_place_ms": _time_ms(inplace_attend_path, kp, vp,
                                       iters=iters),
    }
    wb_ms, (kp, vp) = _time_state_ms(
        lambda kp_, vp_: j_writeback(kp_, vp_, kg, vg), (kp, vp),
        iters=iters)
    row_ms, _ = _time_state_ms(j_write_row, (kp, vp), iters=iters)
    out.update(
        legacy_writeback_page_ms=wb_ms, write_row_ms=row_ms,
        attend_speedup=out["legacy_attend_path_ms"]
        / out["attend_in_place_ms"],
        step_speedup=(out["legacy_attend_path_ms"] + wb_ms)
        / (out["attend_in_place_ms"] + row_ms))
    return out


# ------------------------------------------------------------ prefill TTFT --

def _scatter_prefill_arm(cfg, params, lens, num_pages, page) -> List[float]:
    """The PR-2 prefill dataflow, reconstructed as the baseline: b=1
    contiguous prefill (jitted per prompt length) then a scatter of the
    contiguous cache into pages — the ``write_prefill`` copy the chunked
    path deleted.  → TTFT ms per prompt."""
    from repro.models import build_model
    from repro.serving.core import greedy_token
    from repro.serving.paged import PagedKVCache

    model = build_model(cfg)
    kv = PagedKVCache(model, num_pages, page)

    def write(pool, caches1, ids):
        n = ids.shape[0]

        def wr(pl, one, ax, lax):
            s = one.shape
            one = one.reshape(s[:lax] + (n, page) + s[lax + 1:])
            one = jnp.squeeze(one, ax)
            one = jnp.moveaxis(one, lax - 1, ax)
            return pl.at[(slice(None),) * ax + (ids,)].set(
                one.astype(pl.dtype))

        return jax.tree.map(wr, pool, caches1, kv.axes, kv.laxes)

    scatter = jax.jit(write, donate_argnums=(0,))
    prefill = jax.jit(
        lambda p, t, c: model.prefill(p, {"tokens": t}, c))

    rng = np.random.default_rng(0)
    ttft = []
    for lp in lens:
        prompt = rng.integers(0, cfg.vocab_size, lp).astype(np.int32)
        n0 = kv.pages_needed(lp)
        pages = jnp.arange(n0, dtype=jnp.int32)
        t0 = time.perf_counter()
        fresh = model.init_cache(1, n0 * page)
        logits, c1 = prefill(params, jnp.asarray(prompt)[None], fresh)
        kv.pool = scatter(kv.pool, c1, pages)
        tok = greedy_token(logits[0])
        jax.block_until_ready(kv.pool)
        del tok
        ttft.append((time.perf_counter() - t0) * 1e3)
    return ttft


def _chunked_prefill_arm(cfg, params, lens, num_pages, page,
                         chunk) -> List[float]:
    """Chunked paged prefill through EngineCore at the same page budget:
    submit → step until the first token lands.  → TTFT ms per prompt."""
    from repro.serving import EngineCore, Request

    eng = EngineCore(cfg, params, lanes=1, page_size=page,
                     num_pages=num_pages, chunk_size=chunk,
                     max_len=num_pages * page)
    rng = np.random.default_rng(0)
    ttft = []
    for i, lp in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, lp).astype(np.int32)
        t0 = time.perf_counter()
        eng.submit(Request(uid=i, prompt=prompt, max_new=1))
        while eng.scheduler.has_work():
            out = eng.step()
            if out.tokens:
                break
        ttft.append((time.perf_counter() - t0) * 1e3)
        eng.run()                         # drain the tail, free the pages
        eng.finished.clear()
    return ttft


def _prefill_results(tiny: bool) -> Dict[str, Any]:
    """TTFT on long prompts: chunked vs scatter at equal page budget.

    ``distinct``: a stream of all-different prompt lengths — the scatter
    path re-jits its b=1 prefill for every length, the chunked path reuses
    its small static bucket set.  Both arms first serve a *warm-up* stream
    of lengths disjoint from the measured ones: that covers the chunked
    arm's one-time (bucket × table-width) compile keys — a bounded set a
    long-running server crosses once — while leaving the scatter arm's
    pathology untouched (its compiles are per *length*, and the warm-up
    lengths are all different from the measured ones).  ``warm``: the same
    length twice, keeping only the second (steady-state compute, compile
    excluded).
    """
    from repro.configs import get_config
    from repro.models import build_model

    page = 8 if tiny else 16
    chunk = 4 * page          # prefill-only lanes: bigger chunks, no padding
    if tiny:
        lens = [40, 44, 52, 60]
    else:
        lens = [384, 400, 432, 464, 496]
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    num_pages = -(-max(lens) // page) + 2     # equal budget for both arms
    # One chunk shorter than each measured length (same final-chunk
    # remainder — the ragged bucket key — never the same length) plus one
    # near-max length that reaches the widest table bucket.  At bench
    # scale this covers the chunked arm's compile keys exactly; at --tiny
    # the short warm prompts cannot reach every (bucket × width) combo, so
    # tiny distinct medians retain some compile cost (tiny CI is
    # crash-only; cross-PR TTFT comparisons should use the full run).
    warm_lens = sorted({w for w in
                        [lp - chunk for lp in lens] + [max(lens) - 1]
                        if w >= 1 and w not in set(lens)})

    arms = {}
    for mode, fn in (("scatter", lambda ls: _scatter_prefill_arm(
                          cfg, params, ls, num_pages, page)),
                     ("chunked", lambda ls: _chunked_prefill_arm(
                          cfg, params, ls, num_pages, page, chunk))):
        distinct = fn(warm_lens + lens)[len(warm_lens):]
        warm = min(fn([lens[0]] * 4)[1:])     # best-of-3 after compile
        arms[mode] = {"prefill_mode": mode,
                      "warmup_lens": warm_lens,
                      "ttft_ms_distinct": distinct,
                      "ttft_ms_distinct_median": _pct(distinct, 50),
                      "ttft_ms_warm": warm}
    return {"page_size": page, "chunk_size": chunk, "num_pages": num_pages,
            "prompt_lens": lens,
            "scatter": arms["scatter"], "chunked": arms["chunked"],
            "ttft_speedup_distinct":
                arms["scatter"]["ttft_ms_distinct_median"]
                / arms["chunked"]["ttft_ms_distinct_median"],
            "ttft_speedup_warm": arms["scatter"]["ttft_ms_warm"]
                / arms["chunked"]["ttft_ms_warm"]}


# ------------------------------------------------------ speculative decode --

def _spec_traffic(vocab: int, tiny: bool, repetitive: bool, seed: int = 5):
    """Traffic for the draft-then-verify A/B.

    ``repetitive``: N *identical* greedy requests.  Greedy decoding is
    deterministic, so every request regenerates the same stream; after the
    first finishes, the n-gram proposer's history ring replays it and the
    verify accepts nearly every draft — the lookup-friendly best case
    (agentic retries, self-consistency sampling, templated output).

    ``repetitive=False``: all-distinct random prompts, used by the
    adversarial/rejection arms below.
    """
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    n = 6 if tiny else 16
    lp = 12 if tiny else 48
    max_new = 16 if tiny else 32
    if repetitive:
        base = rng.integers(0, vocab, lp).astype(np.int32)
        prompts = [base.copy() for _ in range(n)]
    else:
        prompts = [rng.integers(0, vocab, lp).astype(np.int32)
                   for _ in range(n)]
    return [Request(uid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]


def _no_drafts(stream, k):
    """The n-gram proposer's behaviour on genuinely lookup-hostile traffic:
    no trailing n-gram ever recurs, so it returns no drafts.  Modelled
    explicitly because the *random-weight* smoke model's greedy streams
    settle into short token loops, which would make any real n-gram
    matcher fire on any traffic — a real tokenizer+model stays quiet here.
    """
    return []


class _JunkProposer:
    """Rejection worst case: always drafts k uniform-random tokens, so
    acceptance is ~1/vocab per draft — the engine pays the full 1+k verify
    stream and commits ~1 token.  Bounds the cost of a maximally wrong
    proposer (recorded for trajectory; no CI floor — CPU steps are
    compute-bound, so extra verify rows cost linearly here, unlike the
    bandwidth-bound accelerator regime the feature targets)."""

    def __init__(self, vocab: int, seed: int = 9):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)

    def __call__(self, stream, k):
        return [int(t) for t in self.rng.integers(0, self.vocab, k)]


def _spec_drain(eng, requests) -> Dict[str, Any]:
    """Drain one pass and attach the pass's speculative deltas — a
    registry window (``spec_window``/``spec_summary``), not bench-side
    diffing of engine attributes."""
    since = eng.obs.spec_window()
    res = _instrumented_drain(
        eng, requests, lambda e: e.pages_in_use * e.kv.page_size, core=True)
    res.update(eng.obs.spec_summary(since))
    return res


def _speculative_results(tiny: bool) -> Dict[str, Any]:
    """Spec vs non-spec engine at equal lanes/pages, three arms:

    - ``repetitive`` — identical requests through the n-gram proposer with
      history: near-total acceptance, several tokens per drafting step
      (CI floor ``accepted_per_spec_step > 1.5``);
    - ``adversarial`` — lookup-hostile traffic, proposer never matches so
      no drafts are issued: speculation must cost ~nothing
      (CI floor tok/s ratio ≥ 0.8);
    - ``rejection`` — a maximally wrong proposer, every draft verified and
      thrown away: the worst-case cost bound (recorded, no floor).

    Engines are reused across passes — early passes warm the jit caches
    and (repetitive arm) seed the proposer's history with the finished
    streams — and each arm keeps draining until a pass compiles nothing
    new (``trace_count`` stable), so the reported pass is a warm server,
    never an XLA-compile measurement.  The non-spec baseline serves the
    *same* traffic, so the tok/s ratio isolates the draft/verify
    machinery itself.
    """
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineCore, NGramProposer

    page = 8 if tiny else 16
    lanes = 2 if tiny else 4
    spec_k = 4
    chunk = 2 * page
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    lp, max_new = (12, 16) if tiny else (48, 32)
    req_rows = lp + max_new + spec_k
    num_pages = lanes * -(-req_rows // page) + 4

    def engine(proposer: Any) -> Any:
        kw = {} if proposer is None else dict(
            speculative=True, spec_k=spec_k, proposer=proposer)
        return EngineCore(cfg, params, lanes=lanes, page_size=page,
                          num_pages=num_pages, chunk_size=chunk,
                          max_len=num_pages * page, mode="ragged", **kw)

    arm_defs = (
        ("repetitive", True,
         lambda: NGramProposer(max_ngram=3, history=8)),
        ("adversarial", False, lambda: _no_drafts),
        ("rejection", False, lambda: _JunkProposer(cfg.vocab_size)),
    )
    arms: Dict[str, Any] = {}
    for name, repetitive, mk in arm_defs:
        eng_s, eng_b = engine(mk()), engine(None)
        for _ in range(6):
            t0, b0 = eng_s.trace_count, eng_b.trace_count
            spec = _spec_drain(eng_s, _spec_traffic(cfg.vocab_size, tiny,
                                                    repetitive))
            base = _spec_drain(eng_b, _spec_traffic(cfg.vocab_size, tiny,
                                                    repetitive))
            if eng_s.trace_count == t0 and eng_b.trace_count == b0:
                break
        arms[name] = {"spec": spec, "baseline": base,
                      "tok_s_ratio": spec["tok_s"] / base["tok_s"],
                      "accepted_per_spec_step":
                          spec["accepted_per_spec_step"],
                      "acceptance": spec["acceptance"]}
    return {"page_size": page, "lanes": lanes, "spec_k": spec_k,
            "num_pages": num_pages, "max_new": max_new,
            "proposer": "ngram(max_ngram=3, history=8)",
            # All engines in this section resolve the same per-(model,
            # platform) kernel config; recorded once for attributability.
            "kernel_config": eng_s.kernel_config.describe(),
            "repetitive": arms["repetitive"],
            "adversarial": arms["adversarial"],
            "rejection": arms["rejection"]}


# ------------------------------------------------------------ prefix reuse --

def _prefix_reuse_results(tiny: bool) -> Dict[str, Any]:
    """Shared-system-prompt TTFT: cold prefill vs radix-cache hits.

    One engine, equal memory, the production-redundant stream: every
    request opens with the same S-token page-aligned prefix (S multiple of
    page_size, so hits are whole shared pages and no CoW lands on this
    path) plus a short distinct tail.  A disjoint-prefix warm-up request
    retires the one-time step compiles first, so the cold arm measures
    compute, not XLA; the cache is on throughout, making cold-vs-warm a
    pure reuse delta.  ``prefix_hit_rate`` is the *deterministic* fraction
    of warm-phase known tokens served from resident pages (S / (S+tail) by
    construction) — CI asserts it ≥ 0.9; the TTFT speedup is the wall-clock
    claim (> 1: a warm request streams ~tail tokens instead of S+tail).
    """
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineCore, Request

    page = 8 if tiny else 16
    shared_len = (6 if tiny else 16) * page       # 48 / 256 tokens
    tail_len = 4 if tiny else 16                  # hit_rate 0.923 / 0.941
    n_warm = 5 if tiny else 10
    chunk = 2 * page
    max_new = 4
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    need = shared_len + tail_len + max_new
    num_pages = 2 * -(-need // page) + 4          # requests + resident cache

    eng = EngineCore(cfg, params, lanes=2, page_size=page,
                     num_pages=num_pages, chunk_size=chunk,
                     max_len=num_pages * page, prefix_cache=True)
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, shared_len).astype(np.int32)

    def ttft(uid, prompt):
        t0 = time.perf_counter()
        eng.submit(Request(uid=uid, prompt=prompt, max_new=max_new))
        while eng.scheduler.has_work():
            if eng.step().tokens:
                break
        ms = (time.perf_counter() - t0) * 1e3
        eng.run()                                 # drain tail, publish pages
        eng.finished.clear()
        return ms

    def prompt_for(uid):                          # distinct first tail token
        tail = rng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)
        tail[0] = uid % cfg.vocab_size
        return np.concatenate([shared, tail])

    # compile warm-up on a *disjoint* prefix: same lengths, zero reuse
    ttft(10_000, rng.integers(0, cfg.vocab_size,
                              shared_len + tail_len).astype(np.int32))
    cold_ms = ttft(0, prompt_for(0))              # first sharer: cache miss
    r = eng.obs.registry
    snap = r.snapshot()                           # warm-phase window anchor
    warm_ms = [ttft(uid, prompt_for(uid)) for uid in range(1, 1 + n_warm)]
    # Every reuse aggregate comes straight from the metrics registry: the
    # hit rate is a counter ratio over the warm-phase window, the page
    # telemetry the lifetime counters/gauges the cache itself maintains.
    hit_rate = r.ratio("prefix_hit_tokens_total",
                       "prefix_lookup_tokens_total", since=snap)
    hit_toks = r.delta(snap)["prefix_hit_tokens_total"]

    return {"page_size": page, "chunk_size": chunk, "num_pages": num_pages,
            "kernel_config": eng.kernel_config.describe(),
            "shared_prefix_tokens": int(shared_len),
            "tail_tokens": int(tail_len), "warm_requests": n_warm,
            "cold_ttft_ms": cold_ms, "warm_ttft_ms": warm_ms,
            "warm_ttft_ms_median": _pct(warm_ms, 50),
            "ttft_speedup_warm_vs_cold": cold_ms / _pct(warm_ms, 50),
            "prefix_hit_rate": hit_rate,
            "prefix_hit_tokens": int(hit_toks),
            "pages_shared": int(r.value("prefix_shared_page_grants_total")),
            "cached_pages": int(r.value("prefix_cached_pages")),
            "cow_copies": int(r.value("cow_copies_total")),
            "evicted_pages": int(r.value("prefix_evicted_pages_total"))}


# --------------------------------------------------------------- serve loop --

def _serve_traffic(vocab: int, n: int, max_new: int, seed: int):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(uid=i, max_new=max_new,
                    prompt=rng.integers(0, vocab, int(rng.integers(4, 24))
                                        ).astype(np.int32))
            for i in range(n)]


def _serve_loop_results(tiny: bool) -> Dict[str, Any]:
    """Async streaming front door vs the batch driver, one warm engine.

    Arm 1 (``batch``) is today's driver: submit all N requests at t=0,
    step until drained, record each request's first-token time — late
    admissions pay the whole queue in their TTFT.  Arm 2 (``stream``)
    serves the identical traffic through :class:`AsyncLMServer` with
    Poisson inter-arrivals at 70% of N / batch_elapsed — the throughput
    the engine just proved on this traffic, derated to the classic
    sustained-utilization point so the stream arm is offered a load it
    can actually absorb (at 100% any serving overhead compounds into an
    unbounded backlog and TTFT measures the queue, not the server).
    Both arms run after a full warm-up drain (compile keys retired); the
    deltas are serving policy, not XLA.
    """
    import asyncio

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import AsyncLMServer, EngineCore

    # n >> lanes and long-ish generations: the batch arm's *median* request
    # must actually sit in the admission queue, else both arms just measure
    # prefill and the comparison is noise.
    page = 8 if tiny else 16
    lanes = 2 if tiny else 4
    n = 12 if tiny else 32
    max_new = 16 if tiny else 32
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    need = 24 + max_new
    num_pages = lanes * -(-need // page) + 4
    eng = EngineCore(cfg, params, lanes=lanes, page_size=page,
                     num_pages=num_pages, chunk_size=2 * page,
                     max_len=num_pages * page, mode="ragged")

    def drain(requests):
        for r in requests:
            eng.submit(r)
        while eng.scheduler.has_work():
            eng.step()
        eng.finished.clear()

    async def client(server, req, delay):
        await asyncio.sleep(delay)
        async for _ in server.generate(req):
            pass

    def stream_pass(seed: int, rate: float) -> Dict[str, Any]:
        arrivals = np.cumsum(
            np.random.default_rng(seed + 1).exponential(1.0 / rate, n))

        async def serve():
            async with AsyncLMServer(eng, max_waiting=n) as server:
                await asyncio.gather(*[
                    client(server, r, d) for r, d in
                    zip(_serve_traffic(cfg.vocab_size, n, max_new, seed),
                        arrivals)])
            return server.summary()

        summary = asyncio.run(serve())
        eng.finished.clear()
        return summary

    def batch_pass(seed: int) -> Tuple[Dict[str, Any], float]:
        """Submit-all-then-drain; TTFT/TPOT are the engine-side
        ``request_ttft_ms`` / ``request_tpot_ms`` histograms (windowed by
        observation count), not re-derived from per-step polling."""
        reqs = _serve_traffic(cfg.vocab_size, n, max_new, seed)
        window = eng.obs.engine_window()
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        steps = 0
        while eng.scheduler.has_work():
            eng.step()
            steps += 1
        elapsed = time.perf_counter() - t0
        eng.finished.clear()
        res = {"req_s": n / elapsed, "steps": steps}
        res.update(eng.obs.engine_latency_summary(window))
        return res, elapsed

    drain(_serve_traffic(cfg.vocab_size, n, max_new, seed=0))   # warm jits

    # Both arms repeat until a pass compiles nothing new (the speculative
    # family's convention): seed-1 prompt lengths and staggered arrivals
    # each reach ragged bucket widths the warm drain never does, and an
    # XLA stall in either arm would corrupt the TTFT comparison.
    for _ in range(6):
        c0 = eng.trace_count
        batch, elapsed = batch_pass(seed=1)
        if eng.trace_count == c0:
            break

    # --- stream arm: same engine, Poisson arrivals at 70% of the proven
    # drain rate.  Offering exactly 100% is a knife edge — any per-step
    # serving overhead makes the queue grow without bound over the trace
    # and every client's TTFT becomes the backlog, not the server.  0.7
    # is the classic "sustained utilization" operating point.
    rate = 0.7 * n / elapsed
    for _ in range(6):
        c0 = eng.trace_count
        stream = stream_pass(seed=1, rate=rate)
        if eng.trace_count == c0:
            break

    # --- retrace sentinel: both arms just proved trace-stable, so arm the
    # registry's retrace counter and run one final *measured* pass per
    # arm.  Any jit trace from here is a shape-stability regression (the
    # PR 8 table-width-shrink class of bug); nightly CI pins this at 0.
    eng.obs.mark_warm()
    batch, _ = batch_pass(seed=1)
    stream = stream_pass(seed=1, rate=rate)
    retraces = int(eng.obs.registry.value("step_retraces_total"))
    return {"page_size": page, "lanes": lanes, "requests": n,
            "max_new": max_new, "num_pages": num_pages,
            "poisson_rate_req_s": rate,
            "batch": batch, "stream": stream,
            "retraces_after_warm": retraces,
            "ttft_p50_ratio_stream_vs_batch":
                stream["ttft_ms_p50"] / max(batch["ttft_ms_p50"], 1e-9)}


# ------------------------------------------------------------ observability --

def _observability_results(tiny: bool) -> Dict[str, Any]:
    """Metrics-on vs metrics-off engines on identical mixed traffic.

    The observability layer is host-side python on the step boundary —
    counter bumps, a ring append, gauge writes — so it must be invisible
    next to a jitted model step.  Two otherwise-identical ragged engines
    (one ``metrics=True``, one ``metrics=False``) serve the same traffic;
    both repeat until a pass compiles nothing new, then best-of-3 tok/s
    each, the passes interleaved so machine drift hits both arms alike.
    ``overhead_ratio`` = on/off; the nightly job asserts ≥ 0.98 (≤ 2%
    overhead) at full scale.  At tiny scale a step is sub-millisecond,
    which magnifies the fixed ~tens-of-µs host-side bookkeeping far
    beyond its share at any real step time, so tiny gets the
    noise-tolerant 0.8 floor instead (same stance as the
    adversarial-spec ratio).
    """
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineCore

    page = 8 if tiny else 16
    lanes = 4 if tiny else 16
    max_len = 128 if tiny else 1024
    num_pages = (2 if tiny else 4) * max_len // page
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def engine(metrics: bool):
        return EngineCore(cfg, params, lanes=lanes, page_size=page,
                          num_pages=num_pages, max_len=max_len,
                          chunk_size=2 * page, mode="ragged",
                          metrics=metrics)

    eng_on, eng_off = engine(True), engine(False)

    def drain(eng, seed: int) -> float:
        for r in _mixed_requests(cfg.vocab_size, tiny, seed=seed):
            eng.submit(r)
        t0 = time.perf_counter()
        while eng.scheduler.has_work():
            eng.step()
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in eng.finished)
        eng.finished.clear()
        return toks / dt

    for _ in range(2 if tiny else 3):            # retire the compile keys
        a0, b0 = eng_on.trace_count, eng_off.trace_count
        drain(eng_on, seed=7)
        drain(eng_off, seed=7)
        if eng_on.trace_count == a0 and eng_off.trace_count == b0:
            break
    ons, offs = [], []
    for _ in range(3):                           # interleave the arms
        ons.append(drain(eng_on, seed=7))
        offs.append(drain(eng_off, seed=7))
    on, off = max(ons), max(offs)
    return {"tiny": tiny,
            "page_size": page, "lanes": lanes, "num_pages": num_pages,
            "metrics_on_tok_s": on, "metrics_off_tok_s": off,
            "overhead_ratio": on / off,
            "registry_families": len(eng_on.obs.registry.names()),
            "ring_len": len(eng_on.obs.ring)}


# ----------------------------------------------------------------- driver --

# --------------------------------------------------------- sharded engine --

_SHARDED_SNIPPET = """
import json, time
import numpy as np
import jax
from repro.configs import get_config
from repro.models import build_model
from repro.serving import EngineCore, Request

tiny = {tiny}
page, lanes = 8, 4
num_pages = 32 if tiny else 64
cfg = get_config("deepseek-7b-smoke")
params = build_model(cfg).init(jax.random.PRNGKey(0))

def traffic(seed=7):
    rng = np.random.default_rng(seed)
    n = 6 if tiny else 12
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 40)))
                    .astype(np.int32),
                    max_new=int(rng.integers(4, 12)))
            for i in range(n)]

def arm(mesh):
    eng = EngineCore(cfg, params, lanes=lanes, page_size=page,
                     num_pages=num_pages, chunk_size=2 * page, mesh=mesh)
    for r in traffic():                 # warm pass: compile every bucket
        eng.submit(r)
    eng.run()
    reqs = traffic(seed=8)
    for r in reqs:
        eng.submit(r)
    steps = rows = 0
    t0 = time.perf_counter()
    while eng.scheduler.has_work():
        out = eng.step()
        steps += 1
        rows += out.live_rows
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in reqs)
    per_tok = eng.collective_bytes_per_token
    # Measured (not analytic) per-step collective bytes: an AOT
    # lower+compile of the sharded step at the widest bucket, walked by
    # launch/hlo_analysis.  0 at mesh 1 (no collectives to count).
    measured = eng.measure_collective_bytes()
    return {{"mesh": eng.mesh_size, "tok_s": toks / dt, "steps": steps,
             "tokens": toks, "live_rows": rows,
             "collective_bytes_per_token": per_tok,
             "collective_bytes_per_step": per_tok * rows // max(steps, 1),
             "collective_bytes_per_step_measured": measured,
             "traces": eng.trace_count}}

out = {{"mesh1": arm(None), "mesh2": arm(2)}}
out["tok_s_ratio_mesh2_vs_mesh1"] = (out["mesh2"]["tok_s"]
                                     / out["mesh1"]["tok_s"])
print("RESULT " + json.dumps(out))
"""


def _sharded_results(tiny: bool) -> Dict[str, Any]:
    """Mesh 1 vs mesh 2 on identical traffic, in a 2-device subprocess."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SNIPPET.format(tiny=tiny)],
        capture_output=True, text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded arm failed:\n{proc.stderr[-4000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def run_serving(tiny: bool = False) -> Dict[str, Any]:
    return {"meta": {"platform": jax.default_backend(), "tiny": tiny,
                     "config": "deepseek-7b-smoke"},
            "engines": _engine_results(tiny),
            "step_breakdown": _breakdown_results(tiny),
            "prefill_ttft": _prefill_results(tiny),
            "speculative": _speculative_results(tiny),
            "prefix_reuse": _prefix_reuse_results(tiny),
            "serve_loop": _serve_loop_results(tiny),
            "observability": _observability_results(tiny),
            "sharded": _sharded_results(tiny)}


def write_json(results: Dict[str, Any], path: str = _JSON_DEFAULT) -> None:
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")


def rows_from(results: Dict[str, Any]) -> Iterator[Row]:
    e, bd = results["engines"], results["step_breakdown"]
    pf = results["prefill_ttft"]
    sp = results["speculative"]
    px = results["prefix_reuse"]
    yield ("serving/slot_contiguous_tok_s", e["slot"]["tok_s"],
           f"{e['slot']['tokens']} toks; {e['slot']['lanes']} lanes x "
           f"{e['max_len']} rows = budget")
    yield ("serving/padded_tok_s", e["padded"]["tok_s"],
           f"same budget as {e['num_pages']} x {e['page_size']}-row pages; "
           f"{e['padded']['lanes']} lanes, padded (lanes, C) steps")
    yield ("serving/ragged_tok_s", e["ragged"]["tok_s"],
           f"same budget/lanes, token-level ragged steps "
           f"(buckets {e['token_buckets']})")
    yield ("serving/ragged_speedup", e["speedup"],
           "ragged EngineCore vs slot engine, equal-memory mixed traffic")
    yield ("serving/padded_speedup", e["speedup_padded"],
           "PR-3 padded EngineCore vs slot engine (the padding-tax arm)")
    yield ("serving/ragged_vs_padded_speedup", e["speedup_ragged_vs_padded"],
           "the padding tax itself: same engine, ragged vs padded packing")
    yield ("serving/padding_efficiency_ragged",
           e["ragged"]["padding_efficiency"],
           f"live rows / computed rows ({e['ragged']['live_rows']} / "
           f"{e['ragged']['padded_rows']})")
    yield ("serving/padding_efficiency_padded",
           e["padded"]["padding_efficiency"],
           f"live rows / computed rows ({e['padded']['live_rows']} / "
           f"{e['padded']['padded_rows']})")
    yield ("serving/ragged_step_ms_p50", e["ragged"]["step_ms_p50"],
           "EngineCore ragged step latency (packed prefill+decode stream)")
    yield ("serving/ragged_peak_cache_rows",
           float(e["ragged"]["peak_cache_rows"]),
           f"resident rows at peak (slot engine: "
           f"{e['slot']['peak_cache_rows']} always)")
    yield ("serving/mixed_prefill_decode_steps",
           float(e["ragged"]["mixed_steps"]),
           f"ragged steps batching prefill chunks with decodes "
           f"({e['ragged']['prefill_tokens']} chunk toks streamed)")
    yield ("serving/step_legacy_gather_ms", bd["legacy_gather_ms"],
           "the per-step copy the in-place kernel deleted")
    yield ("serving/step_attend_in_place_ms", bd["attend_in_place_ms"],
           "paged attention through the table (live step, dominant)")
    yield ("serving/step_write_row_ms", bd["write_row_ms"],
           "single-row pool write (live step)")
    yield ("serving/attend_speedup_vs_gather_path", bd["attend_speedup"],
           f"legacy gather+attend {bd['legacy_attend_path_ms']:.3g} ms -> "
           f"in-place {bd['attend_in_place_ms']:.3g} ms at "
           f"{bd['shape']['rows_per_lane']} rows/lane")
    yield ("serving/step_speedup_vs_gather_path", bd["step_speedup"],
           "attend+write vs PR 1 gather+attend+page-writeback")
    yield ("serving/ttft_chunked_ms", pf["chunked"]["ttft_ms_distinct_median"],
           f"median over distinct prompt lens {pf['prompt_lens']}; "
           f"prefill_mode=chunked (c={pf['chunk_size']})")
    yield ("serving/ttft_scatter_ms", pf["scatter"]["ttft_ms_distinct_median"],
           "same stream through the PR-2 contiguous-then-scatter path; "
           "prefill_mode=scatter (re-jits per length)")
    yield ("serving/ttft_speedup_distinct", pf["ttft_speedup_distinct"],
           "chunked vs scatter on all-distinct prompt lengths")
    yield ("serving/ttft_speedup_warm", pf["ttft_speedup_warm"],
           "chunked vs scatter at a repeated (pre-compiled) length")
    rep, adv = sp["repetitive"], sp["adversarial"]
    yield ("serving/spec_accepted_per_step", rep["accepted_per_spec_step"],
           f"extra tokens committed per drafting step, repetitive stream "
           f"(k={sp['spec_k']}, {sp['proposer']}; CI floor 1.5)")
    yield ("serving/spec_acceptance_repetitive", rep["acceptance"],
           f"{rep['spec']['accepted_tokens']} / "
           f"{rep['spec']['drafted_tokens']} drafts accepted over "
           f"{rep['spec']['spec_steps']} drafting steps")
    yield ("serving/spec_tok_s_repetitive", rep["spec"]["tok_s"],
           f"spec engine, {sp['lanes']} lanes; non-spec baseline "
           f"{rep['baseline']['tok_s']:.4g} tok/s on the same stream")
    yield ("serving/spec_speedup_repetitive", rep["tok_s_ratio"],
           f"spec vs non-spec tok/s, lookup-friendly traffic "
           f"({rep['spec']['steps']} vs {rep['baseline']['steps']} steps)")
    yield ("serving/spec_tok_s_ratio_adversarial", adv["tok_s_ratio"],
           f"spec vs non-spec tok/s on lookup-hostile traffic "
           f"({adv['spec']['drafted_tokens']} drafts issued; CI floor 0.8)")
    rej = sp["rejection"]
    yield ("serving/spec_tok_s_ratio_rejection", rej["tok_s_ratio"],
           f"worst case: every draft verified and rolled back "
           f"(acceptance {rej['acceptance']:.3g} over "
           f"{rej['spec']['drafted_tokens']} junk drafts; CPU is "
           f"compute-bound so verify rows cost linearly here)")
    yield ("serving/prefix_cold_ttft_ms", px["cold_ttft_ms"],
           f"first shared-prefix request ({px['shared_prefix_tokens']}+"
           f"{px['tail_tokens']} tokens), compile-warm, cache miss")
    yield ("serving/prefix_warm_ttft_ms", px["warm_ttft_ms_median"],
           f"median of {px['warm_requests']} cache-hit requests "
           f"(stream only the {px['tail_tokens']}-token tail)")
    yield ("serving/prefix_ttft_speedup", px["ttft_speedup_warm_vs_cold"],
           "warm vs cold TTFT on the shared-prefix workload, same engine")
    yield ("serving/prefix_hit_rate", px["prefix_hit_rate"],
           f"warm-phase known tokens served from resident pages "
           f"({px['prefix_hit_tokens']} hit; deterministic)")
    yield ("serving/prefix_pages_shared", float(px["pages_shared"]),
           f"shared-page grants across admissions "
           f"({px['cached_pages']} pages resident in the radix cache, "
           f"{px['cow_copies']} CoW copies)")
    sl = results["serve_loop"]
    yield ("serving/serve_loop_stream_req_s", sl["stream"]["req_s"],
           f"AsyncLMServer, Poisson arrivals at the self-calibrated "
           f"{sl['poisson_rate_req_s']:.3g} req/s over {sl['requests']} "
           f"requests, {sl['lanes']} lanes")
    yield ("serving/serve_loop_stream_ttft_ms_p50",
           sl["stream"]["ttft_ms_p50"],
           "submit -> first streamed token, per-client arrival clock")
    yield ("serving/serve_loop_stream_ttft_ms_p99",
           sl["stream"]["ttft_ms_p99"],
           "streaming TTFT tail under Poisson arrivals")
    yield ("serving/serve_loop_stream_tpot_ms", sl["stream"]["tpot_ms"],
           "mean inter-token time after the first, streaming clients")
    yield ("serving/serve_loop_batch_ttft_ms_p50", sl["batch"]["ttft_ms_p50"],
           f"batch driver (submit-all at t=0): median request pays the "
           f"admission queue in its TTFT ({sl['batch']['steps']} steps)")
    yield ("serving/serve_loop_ttft_p50_ratio",
           sl["ttft_p50_ratio_stream_vs_batch"],
           "streaming vs batch TTFT p50, same warm engine + traffic "
           "(CI floor: <= 1)")
    yield ("serving/serve_loop_retraces_after_warm",
           float(sl["retraces_after_warm"]),
           "jit traces during the measured post-warm batch+stream passes "
           "(retrace sentinel; nightly CI pins this at 0)")
    ob = results["observability"]
    yield ("serving/obs_overhead_ratio", ob["overhead_ratio"],
           f"metrics-on / metrics-off tok/s on identical mixed traffic "
           f"({ob['metrics_on_tok_s']:.4g} vs {ob['metrics_off_tok_s']:.4g}"
           f"; nightly CI floor 0.98 full / 0.8 tiny — sub-ms tiny steps "
           f"magnify the fixed host-side cost)")
    sh = results["sharded"]
    yield ("serving/sharded_tok_s_mesh1", sh["mesh1"]["tok_s"],
           f"single-device ragged engine in the 2-device subprocess "
           f"({sh['mesh1']['tokens']} toks over {sh['mesh1']['steps']} steps)")
    yield ("serving/sharded_tok_s_mesh2", sh["mesh2"]["tok_s"],
           "same traffic, KV-head-sharded pool + shard_map step at mesh 2")
    yield ("serving/sharded_tok_s_ratio", sh["tok_s_ratio_mesh2_vs_mesh1"],
           "mesh 2 vs mesh 1 tok/s — recorded, NO CPU floor (placeholder "
           "devices share the same cores; overhead signal only)")
    yield ("serving/sharded_collective_bytes_per_token",
           float(sh["mesh2"]["collective_bytes_per_token"]),
           f"analytic all-gather bytes received per device per token row "
           f"at mesh 2 (per step: {sh['mesh2']['collective_bytes_per_step']}"
           f" B; mesh 1: {sh['mesh1']['collective_bytes_per_token']} B)")
    yield ("serving/sharded_collective_bytes_per_step_measured",
           float(sh["mesh2"]["collective_bytes_per_step_measured"]),
           "per-device collective bytes per widest-bucket step, counted "
           "from the compiled HLO (launch/hlo_analysis walk; nightly CI "
           "asserts > 0 at mesh 2)")


def bench_paged_serving() -> Iterator[Row]:
    results = run_serving()
    write_json(results)                 # benchmarks.run refreshes the JSON
    yield from rows_from(results)


ALL_SERVING = (bench_paged_serving,)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serving benchmarks -> CSV rows + BENCH_serving.json")
    ap.add_argument("--json", default=_JSON_DEFAULT,
                    help="output path for the JSON results")
    ap.add_argument("--tiny", action="store_true",
                    help="CI scale: small pools/traffic, crash-test numbers")
    args = ap.parse_args()
    results = run_serving(tiny=args.tiny)
    write_json(results, args.json)
    print("name,value,derived")
    for name, value, note in rows_from(results):
        print(f"{name},{value:.6g},{note}")
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
