"""Serving throughput: slot-contiguous vs paged KV cache at mixed lengths.

Both engines get the SAME resident-KV budget (total cache rows) and the same
mixed traffic — a couple of long generations among many short ones.  The
slot engine must size every slot for the longest request it may host, so the
budget buys ``budget // max_len`` concurrent lanes; the paged engine spends
rows page-by-page as sequences actually grow, so the same budget sustains
far more concurrent short requests while a long one is resident.  Decode
throughput then follows concurrency — this is the serving-side restatement
of HASTILY's O(l)-not-O(l_max) memory claim.

A second pair of rows reports per-engine *step width* (rows attended per
decode step): the paged view is sized by the longest active sequence, the
slot view by ``max_len`` always.

CPU numbers are relative A/B signals, not TPU claims (see docs/benchmarks.md).
"""
from __future__ import annotations

import time
from typing import Iterator, List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str]

_PAGE = 16
_MAX_LEN = 1024                      # serving SLA: longest hostable request
_BUDGET_ROWS = 4 * _MAX_LEN          # resident-KV budget for both engines


def _mixed_requests(vocab: int, seed: int = 7):
    """Many short requests + two long-prompt ones.

    The long prompts (not long generations) force the slot engine's
    ``max_len`` up — every lane reserves _MAX_LEN (1024) rows so such
    requests can land anywhere — while the paged engine spends the 25 pages
    a 384+8-row sequence actually needs, only while it is resident.  All
    generations are short, so drain time is set by queueing (lanes), not by
    one long tail.
    """
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    prompts: List[int] = [4 + (i % 3) * 2 for i in range(48)] + [384, 384]
    return [Request(uid=i, prompt=rng.integers(0, vocab, lp
                                               ).astype(np.int32), max_new=8)
            for i, lp in enumerate(prompts)]


def _drain_tok_s(engine, requests) -> Tuple[float, int]:
    for r in requests:
        engine.submit(r)
    t0 = time.perf_counter()
    done = list(engine.run())
    dt = time.perf_counter() - t0
    engine.finished.clear()             # engine is reused across passes
    toks = sum(len(r.tokens) for r in done)
    return toks / dt, toks


def bench_paged_serving() -> Iterator[Row]:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedServingEngine, ServingEngine
    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    slot_lanes = _BUDGET_ROWS // _MAX_LEN          # 4 lanes of 1024 rows
    paged_lanes = 16                               # page pool spreads wider
    num_pages = _BUDGET_ROWS // _PAGE

    # Engines are REUSED across passes: pass 1-2 warm the jit caches
    # (per-width decode buckets, per-length prefill buckets), pass 3 is the
    # steady-state measurement a long-running server actually sees.
    slot_eng = ServingEngine(cfg, params, slots=slot_lanes, max_len=_MAX_LEN)
    paged_eng = PagedServingEngine(cfg, params, slots=paged_lanes,
                                   page_size=_PAGE, num_pages=num_pages,
                                   max_len=_MAX_LEN)
    for _ in range(3):
        slot_tok_s, n = _drain_tok_s(slot_eng, _mixed_requests(cfg.vocab_size))
        paged_tok_s, _ = _drain_tok_s(paged_eng,
                                      _mixed_requests(cfg.vocab_size))

    yield ("serving/slot_contiguous_tok_s", slot_tok_s,
           f"{n} toks; {slot_lanes} lanes x {_MAX_LEN} rows = budget")
    yield ("serving/paged_tok_s", paged_tok_s,
           f"same budget as {num_pages} x {_PAGE}-row pages; "
           f"{paged_lanes} lanes")
    yield ("serving/paged_speedup", paged_tok_s / slot_tok_s,
           "equal-memory mixed-length traffic; >1 means paging pays")
    yield ("serving/slot_step_rows", float(_MAX_LEN),
           "rows attended per decode step (always max_len)")
    yield ("serving/paged_step_rows_max", float(_PAGE * 32),
           "upper bound: longest active seq (392 rows) -> 32-page view")


ALL_SERVING = (bench_paged_serving,)
