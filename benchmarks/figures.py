"""One module per paper figure: each emits the figure's data as CSV rows.

Figures 7–13 come from the analytical CIM model (the reproduction of the
paper's simulator evaluation); each row also carries the paper's published
value where one exists, so the reproduction error is visible inline.
"""
from __future__ import annotations

from typing import Iterator, Tuple

from repro.perfmodel import (DEFAULT_HW as HW, GPU, encoder_layer_energy_j,
                             encoder_layer_latency_s, end_to_end_tops,
                             softmax_energy_j, softmax_fraction,
                             softmax_latency_s, tops_per_watt)

Row = Tuple[str, float, str]

SEQ_SWEEP = (128, 256, 512, 1024, 2048, 4096, 8192)
EMB_SWEEP = (512, 768, 1024)


def fig7_softmax_latency() -> Iterator[Row]:
    """Softmax latency per vector × l × mode × ALU width (paper Fig 7)."""
    paper = {("puma", 8192, 16): 22.13, ("uclm", 8192, 16): 6.0,
             ("multicore", 8192, 16): 1.36}
    for mode in ("puma", "uclm", "multicore"):
        for l in SEQ_SWEEP:
            for w in (16, 64):
                us = softmax_latency_s(HW, l, mode, w) * 1e6
                p = paper.get((mode, l, w))
                note = f"paper={p}" if p else ""
                yield (f"fig7/softmax_{mode}_l{l}_w{w}", us, note)


def fig8_softmax_energy() -> Iterator[Row]:
    """Softmax energy per vector (paper Fig 8; ratio ≈1.6 for l>1024)."""
    for mode in ("puma", "uclm", "multicore"):
        for l in SEQ_SWEEP:
            nj = softmax_energy_j(HW, l, mode) * 1e9
            ratio = (softmax_energy_j(HW, l, "puma")
                     / softmax_energy_j(HW, l, mode))
            yield (f"fig8/softmax_energy_{mode}_l{l}", nj,
                   f"puma_ratio={ratio:.2f}")


def fig9_layer_latency() -> Iterator[Row]:
    """Encoder-layer latency × (softmax accel, pipelining) (paper Fig 9)."""
    arms = [("puma", "none"), ("hastily", "none"),
            ("puma", "coarse"), ("hastily", "fine")]
    for d in EMB_SWEEP:
        for l in SEQ_SWEEP:
            base = encoder_layer_latency_s(HW, l, d, softmax_mode="puma",
                                           pipelined="none")
            for sm, pipe in arms:
                us = encoder_layer_latency_s(HW, l, d, softmax_mode=sm,
                                             pipelined=pipe) * 1e6
                yield (f"fig9/layer_d{d}_l{l}_{sm}_{pipe}", us,
                       f"speedup_vs_puma={base / (us / 1e6):.2f}")


def fig10_runtime_breakdown() -> Iterator[Row]:
    """Softmax share of un-pipelined layer runtime (paper Fig 10)."""
    paper = {("puma", 1024, 768): 0.38, ("hastily", 1024, 768): 0.13}
    for d in (768, 1024):
        for l in (512, 1024):
            for mode in ("puma", "hastily"):
                frac = softmax_fraction(HW, l, d, mode)
                p = paper.get((mode, l, d))
                yield (f"fig10/softmax_frac_{mode}_d{d}_l{l}", frac * 100,
                       f"paper={p * 100:.0f}%" if p else "")


def fig11_layer_energy() -> Iterator[Row]:
    """Encoder-layer energy (paper Fig 11)."""
    for d in EMB_SWEEP:
        for l in SEQ_SWEEP:
            for mode in ("puma", "hastily"):
                uj = encoder_layer_energy_j(HW, l, d, softmax_mode=mode) * 1e6
                yield (f"fig11/layer_energy_{mode}_d{d}_l{l}", uj, "")


def fig12_end2end_tops() -> Iterator[Row]:
    """End-to-end TOPS, BERT-Base/Large × batch (paper Fig 12)."""
    models = {"bert_base": (12, 768, 3072, 158.0),
              "bert_large": (24, 1024, 4096, 263.0)}
    for name, (n, d, ff, paper_tops) in models.items():
        for batch in (1, 2, 4):
            t = end_to_end_tops(HW, n, 512, d, ff, batch=batch)
            note = f"paper={paper_tops} (b>=2)" if batch >= 2 else \
                f"gpu={GPU.tops_bert_base_b1}" if name == "bert_base" else ""
            yield (f"fig12/tops_{name}_b{batch}", t, note)
        puma = end_to_end_tops(HW, n, 512, d, ff, pipelined="coarse",
                               softmax_mode="puma", batch=1)
        yield (f"fig12/tops_puma_{name}_b1", puma,
               "paper=26" if name == "bert_base" else "")


def fig13_energy_efficiency() -> Iterator[Row]:
    """TOPS/W (paper Fig 13: HASTILY ≈ 8, GPU 0.3–0.9)."""
    models = {"bert_base": (12, 768, 3072), "bert_large": (24, 1024, 4096)}
    for name, (n, d, ff) in models.items():
        for batch in (1, 2, 4):
            tw = tops_per_watt(HW, n, 512, d, ff, batch=batch)
            yield (f"fig13/tops_w_{name}_b{batch}", tw, "paper~8")
    yield ("fig13/tops_w_gpu_b1", GPU.tops_w_b1, "paper anchor")
    yield ("fig13/tops_w_gpu_b4", GPU.tops_w_b4, "paper anchor")


ALL_FIGURES = (fig7_softmax_latency, fig8_softmax_energy, fig9_layer_latency,
               fig10_runtime_breakdown, fig11_layer_energy,
               fig12_end2end_tops, fig13_energy_efficiency)
