"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Emits ``name,value,derived`` CSV rows:
- fig7-fig13 — the paper's tables/figures from the analytical CIM model,
  annotated with the paper's published values;
- roofline/* — per-(arch × shape × mesh) terms from the dry-run JSONs;
- micro/* — wall-clock microbenchmarks of the JAX/Pallas code on this host.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on row names")
    ap.add_argument("--skip-micro", action="store_true")
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the slot-vs-paged serving A/B (the slowest "
                         "family: drains mixed traffic through two engines)")
    args = ap.parse_args()

    from benchmarks.figures import ALL_FIGURES
    from benchmarks.roofline import roofline_rows
    from benchmarks.microbench import ALL_MICRO
    from benchmarks.serving_bench import ALL_SERVING

    print("name,value,derived")

    def emit(rows):
        for name, value, note in rows:
            if args.only and args.only not in name:
                continue
            print(f"{name},{value:.6g},{note}")

    for fig in ALL_FIGURES:
        emit(fig())
    try:
        emit(roofline_rows())
    except Exception as e:                                    # noqa: BLE001
        print(f"roofline/error,0,{e!r}", file=sys.stderr)
    if not args.skip_micro:
        for micro in ALL_MICRO:
            emit(micro())
    if not args.skip_serving:
        for bench in ALL_SERVING:
            emit(bench())


if __name__ == "__main__":
    main()
