"""Checkpointing: atomicity, verification, retention, async, elasticity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore, retain,
                              save, steps)


def tree(rng):
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 8))
                                        ).astype(jnp.bfloat16),
                       "b": jnp.asarray(rng.normal(size=(8,))
                                        ).astype(jnp.float32)},
            "opt": [jnp.ones((3,), jnp.int32), jnp.zeros((2, 2))]}


def test_roundtrip_bitexact(tmp_path, rng):
    t = tree(rng)
    save(str(tmp_path), 7, t)
    ref = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got, step, _ = restore(str(tmp_path), ref)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype    # bf16 survives npz round trip


def test_latest_and_retention(tmp_path, rng):
    t = tree(rng)
    for s in (1, 5, 3, 9):
        save(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 9
    retain(str(tmp_path), keep=2)
    assert steps(str(tmp_path)) == [5, 9]


def test_half_written_checkpoint_ignored(tmp_path, rng):
    t = tree(rng)
    save(str(tmp_path), 1, t)
    # simulate crash mid-write: a step dir without manifest
    os.makedirs(tmp_path / "step_00000099")
    assert latest_step(str(tmp_path)) == 1


def test_corruption_detected(tmp_path, rng):
    t = tree(rng)
    path = save(str(tmp_path), 2, t)
    m = json.load(open(os.path.join(path, "manifest.json")))
    key = next(iter(m["leaves"]))
    m["leaves"][key]["sha256"] = "0" * 16
    json.dump(m, open(os.path.join(path, "manifest.json"), "w"))
    ref = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    with pytest.raises(IOError, match="corruption"):
        restore(str(tmp_path), ref)


def test_shape_mismatch_rejected(tmp_path, rng):
    t = tree(rng)
    save(str(tmp_path), 3, t)
    bad = dict(t)
    bad["params"] = {"w": jnp.zeros((5, 5), jnp.bfloat16),
                     "b": t["params"]["b"]}
    ref = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), bad)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(str(tmp_path), ref)


def test_async_checkpointer(tmp_path, rng):
    t = tree(rng)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, t)
    ck.wait()
    assert steps(str(tmp_path)) == [20, 30]


def test_elastic_restore_onto_mesh(tmp_path, rng):
    """Restore re-shards onto a (1-device) mesh — the elastic path."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    t = {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
    save(str(tmp_path), 1, t)
    mesh = make_host_mesh()
    specs = {"w": P()}
    ref = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got, _, _ = restore(str(tmp_path), ref, mesh=mesh, specs=specs)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding.mesh.shape == mesh.shape
