"""Pallas kernel ↔ pure-jnp oracle allclose sweeps (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels import (attention_ref, int8_matmul, int8_matmul_ref,
                           lut_exp, lut_exp_ref, streaming_attention)


# ---------------------------------------------------------------- lut_exp --

@pytest.mark.parametrize("shape", [(7,), (128,), (3, 5, 11), (256, 128),
                                   (1, 1), (1000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_exp_kernel_sweep(rng, shape, dtype):
    x = jnp.asarray(rng.uniform(-20, 20, size=shape).astype(np.float32)
                    ).astype(dtype)
    got = lut_exp(x)
    want = lut_exp_ref(x.astype(jnp.float32)).astype(dtype)
    assert got.dtype == dtype and got.shape == shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("order", [0, 1])
def test_lut_exp_kernel_orders(rng, order):
    x = jnp.asarray(rng.uniform(-10, 10, size=(513,)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(lut_exp(x, order=order)),
                               np.asarray(lut_exp_ref(x, order=order)),
                               rtol=1e-6)


def test_lut_exp_kernel_edge_values():
    x = jnp.array([-1e30, -100.0, 0.0, 80.0], jnp.float32)
    np.testing.assert_allclose(np.asarray(lut_exp(x)),
                               np.asarray(lut_exp_ref(x)), rtol=1e-6)


# ------------------------------------------------------ streaming attention --

ATTN_CASES = [
    dict(b=2, hq=4, hkv=4, lq=64, lkv=64, d=16, causal=True),
    dict(b=1, hq=8, hkv=2, lq=48, lkv=48, d=32, causal=True),
    dict(b=1, hq=4, hkv=4, lq=32, lkv=96, d=16, causal=True, q_offset=64),
    dict(b=2, hq=4, hkv=2, lq=64, lkv=64, d=16, causal=True, window=16),
    dict(b=1, hq=2, hkv=2, lq=40, lkv=40, d=16, causal=False, cap=30.0),
    dict(b=1, hq=2, hkv=2, lq=64, lkv=64, d=16, causal=True,
         exp_mode="exact"),
    dict(b=1, hq=2, hkv=1, lq=8, lkv=72, d=8, causal=True, q_offset=64,
         kv_len=70),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_attention_kernel_sweep(rng, case):
    c = dict(case)
    q = jnp.asarray(rng.normal(
        size=(c.pop("b"), c.pop("hq"), c.pop("lq"), c["d"])).astype(np.float32))
    k = jnp.asarray(rng.normal(
        size=(q.shape[0], c.pop("hkv"), c.pop("lkv"), c.pop("d"))
        ).astype(np.float32))
    v = jnp.asarray(rng.normal(size=k.shape).astype(np.float32))
    em = c.pop("exp_mode", "lut")
    out = streaming_attention(q, k, v, block_q=16, block_k=16, exp_mode=em,
                              **c)
    ref = attention_ref(q, k, v, exp_mode=em, **c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


def test_attention_kernel_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 4, 32, 16))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 16))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 16))).astype(jnp.bfloat16)
    out = streaming_attention(q, k, v, causal=True, block_q=16, block_k=16)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=3e-2)


# ------------------------------------------------------------- int8 matmul --

@pytest.mark.parametrize("mkn", [(64, 256, 128), (17, 300, 130),
                                 (4, 128, 512), (257, 1024, 384), (1, 128, 128)])
def test_int8_matmul_kernel_sweep(rng, mkn):
    m, k, n = mkn
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = quantize(jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)),
                 axis=0)
    out = int8_matmul(x, w, block_m=16, block_n=128, block_k=128)
    ref = int8_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_int8_matmul_batched(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 256)).astype(np.float32))
    w = quantize(jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)),
                 axis=0)
    out = int8_matmul(x, w, block_m=8, block_n=128, block_k=128)
    assert out.shape == (2, 3, 64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(int8_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-4)


def test_int8_matmul_quant_error_bounded(rng):
    """int8 quantisation error vs f32 matmul stays at the ~1% level."""
    x = jnp.asarray(rng.normal(size=(64, 512)).astype(np.float32))
    wf = jnp.asarray(rng.normal(size=(512, 128)).astype(np.float32))
    out = int8_matmul(x, quantize(wf, axis=0), block_m=16)
    rel = float(jnp.linalg.norm(out - x @ wf) / jnp.linalg.norm(x @ wf))
    assert rel < 0.03, rel


# --------------------------------------------------- model-integrated path --

def test_pallas_backend_selectable(rng):
    """attn_impl="pallas": kernel forward + jnp flash backward, grads equal
    to the pure-jnp streaming path."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("deepseek-7b-smoke")
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    lp, _ = build_model(cfg.replace(attn_impl="pallas")).loss(params, batch)
    ls, _ = build_model(cfg).loss(params, batch)
    assert abs(float(lp) - float(ls)) < 1e-3
    gp = jax.grad(lambda p: build_model(cfg.replace(attn_impl="pallas")
                                        ).loss(p, batch)[0])(params)
    gs = jax.grad(lambda p: build_model(cfg).loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gs)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)
