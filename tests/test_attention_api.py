"""Attention-backend registry: dispatch, resolution, backend equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention_api import (AttentionCall, attention,
                                      backend_for_config, describe_call,
                                      get_backend, list_backends,
                                      register_backend, resolve_backend,
                                      _REGISTRY)


def qkv(rng, b=2, hq=4, hkv=2, lq=24, lkv=24, d=16):
    q = jnp.asarray(rng.normal(size=(b, hq, lq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, lkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, lkv, d)).astype(np.float32))
    return q, k, v


# --------------------------------------------------------- registry basics --

def test_builtin_backends_registered():
    assert {"naive", "naive_decode", "jnp", "pallas", "ring"} <= set(
        list_backends())


def test_unknown_backend_raises():
    rng = np.random.default_rng(0)
    q, k, v = qkv(rng)
    with pytest.raises(KeyError, match="unknown attention backend"):
        attention(q, k, v, backend="flash3")


def test_register_custom_backend_dispatches():
    @register_backend("all_ones_test", supports=lambda call: True)
    def ones_backend(q, k, v, **kw):
        return jnp.ones_like(q)
    try:
        rng = np.random.default_rng(0)
        q, k, v = qkv(rng)
        out = attention(q, k, v, backend="all_ones_test")
        assert bool(jnp.all(out == 1.0))
    finally:
        del _REGISTRY["all_ones_test"]


def test_backend_for_config_legacy_mapping():
    assert backend_for_config("auto", "streaming") == "auto"
    assert backend_for_config("auto", "naive") == "naive"
    assert backend_for_config("auto", "pallas") == "pallas"
    assert backend_for_config("jnp", "naive") == "jnp"   # explicit wins


# ------------------------------------------------------------- resolution --

def _call(**kw):
    base = dict(lq=16, lkv=16, platform="cpu", static_lengths=True,
                has_kv_pos=False, inside_shard_map=False)
    base.update(kw)
    return AttentionCall(**base)


def test_auto_resolution_cpu():
    # multi-row on CPU → streaming jnp; single row → naive O(L) fast path
    assert resolve_backend("auto", _call()).name == "jnp"
    assert resolve_backend("auto", _call(lq=1)).name == "naive_decode"
    # inside shard_map only the ring backend applies
    assert resolve_backend("auto", _call(inside_shard_map=True)).name == "ring"


def test_auto_resolution_tpu_prefers_pallas():
    assert resolve_backend("auto", _call(platform="tpu")).name == "pallas"
    # dynamic lengths / ring positions disqualify the kernel
    assert resolve_backend(
        "auto", _call(platform="tpu", static_lengths=False)).name == "jnp"
    assert resolve_backend(
        "auto", _call(platform="tpu", has_kv_pos=True)).name == "jnp"


def test_explicit_unsupported_raises_and_fallback_degrades():
    spec_call = _call(has_kv_pos=True)
    with pytest.raises(ValueError, match="does not support"):
        resolve_backend("pallas", spec_call)
    assert resolve_backend("pallas", spec_call, fallback=True).name == "jnp"


def test_describe_call_static_vs_traced():
    rng = np.random.default_rng(0)
    q, k, _ = qkv(rng)
    assert describe_call(q, k, q_offset=0, kv_len=8).static_lengths
    traced = jnp.asarray(3, jnp.int32)
    assert not describe_call(q, k, q_offset=traced).static_lengths


# ------------------------------------------- backend equivalence vs naive --

CFGS = [dict(causal=True),
        dict(causal=False),
        dict(causal=True, window=9),
        dict(causal=True, cap=20.0),
        dict(causal=True, window=7, cap=15.0)]


@pytest.mark.parametrize("kw", CFGS)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backends_match_naive(backend, kw, rng):
    q, k, v = qkv(rng)
    want = np.asarray(attention(q, k, v, backend="naive", exp_mode="lut",
                                **kw))
    got = np.asarray(attention(q, k, v, backend=backend, block_k=8,
                               exp_mode="lut", **kw))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("kw", CFGS[:3])
def test_decode_row_matches_naive(kw, rng):
    """lq=1 auto path (naive_decode) == naive with a q_offset/kv_len cache."""
    q, k, v = qkv(rng, lq=1, lkv=32)
    want = np.asarray(attention(q, k, v, backend="naive", q_offset=20,
                                kv_len=21, **kw))
    got = np.asarray(attention(q, k, v, backend="auto", q_offset=20,
                               kv_len=21, **kw))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_ring_backend_via_shard_map(rng):
    """The "ring" backend dispatches inside shard_map (1-device mesh here;
    the 4/8-chip equivalence lives in test_ring_attention.py)."""
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.parallel.compat import make_mesh, shard_map
    q, k, v = qkv(rng)
    mesh = make_mesh((1,), ("sp",))
    f = shard_map(
        functools.partial(attention, backend="ring", axis_name="sp",
                          causal=True),
        mesh=mesh,
        in_specs=(P(None, None, "sp"), P(None, None, "sp"),
                  P(None, None, "sp")),
        out_specs=P(None, None, "sp"))
    got = np.asarray(f(q, k, v))
    want = np.asarray(attention(q, k, v, backend="naive", causal=True,
                                exp_mode="lut"))
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=1e-4)


def test_pallas_backend_grad_matches_jnp(rng):
    """Kernel forward + jnp flash backward: grads equal the jnp backend's."""
    q, k, v = qkv(rng, b=1, lq=16, lkv=16)

    def loss(backend):
        def f(q, k, v):
            return jnp.sum(attention(q, k, v, backend=backend, causal=True,
                                     block_k=8) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    gp = loss("pallas")
    gs = loss("jnp")
    for a, b in zip(gp, gs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-3)


def test_model_config_backend_threading(rng):
    """cfg.attn_backend reaches the layers: pinning "naive" vs "jnp" both
    run, agree, and a bogus name fails fast at build_model."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("deepseek-7b-smoke")
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ln, _ = build_model(cfg.replace(attn_backend="naive")).loss(params, batch)
    lj, _ = build_model(cfg.replace(attn_backend="jnp")).loss(params, batch)
    assert abs(float(ln) - float(lj)) < 1e-3
    with pytest.raises(KeyError, match="unknown attention backend"):
        build_model(cfg.replace(attn_backend="flashinfer"))
