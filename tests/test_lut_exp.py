"""LUT-exponential: the paper's §III-B1 error bounds + decomposition laws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.core.lut_exp import (K, LN2, decompose, lut_exp, lut_exp2,
                                make_table, pow2_int)


def test_table_values():
    t = np.asarray(make_table())
    assert t.shape == (K,)
    np.testing.assert_allclose(t, 2.0 ** (np.arange(K) / K), rtol=1e-7)
    assert t[0] == 1.0 and t[-1] < 2.0


def test_paper_error_bound_order1():
    """Paper: K=128 with e^r ≈ 1+r gives error < 0.0015%."""
    x = jnp.linspace(-20.0, 20.0, 200_001)
    rel = np.abs(np.asarray(lut_exp(x, order=1)) / np.exp(np.asarray(x)) - 1)
    # paper's analytic bound + f32 rounding headroom (measured 1.55e-5)
    assert rel.max() < 0.0015e-2 * 1.1, rel.max()


def test_paper_error_bound_order0():
    """Paper: K=128 with e^r ≈ 1 gives error < 0.54%."""
    x = jnp.linspace(-20.0, 20.0, 200_001)
    rel = np.abs(np.asarray(lut_exp(x, order=0)) / np.exp(np.asarray(x)) - 1)
    assert rel.max() < 0.54e-2 * 1.02, rel.max()


def test_edge_cases():
    x = jnp.array([-jnp.inf, -1e5, -100.0, 0.0, 88.0])
    y = np.asarray(lut_exp(x))
    assert y[0] == 0.0 and y[1] == 0.0 and y[2] == 0.0   # masked positions
    assert y[3] == 1.0
    assert np.isfinite(y[4])


def test_pow2_int_exact():
    n = jnp.arange(-126.0, 128.0)
    np.testing.assert_array_equal(np.asarray(pow2_int(n)),
                                  2.0 ** np.asarray(n))
    assert float(pow2_int(jnp.array(-127.0))) == 0.0   # flush to zero


@given(st.floats(min_value=-80.0, max_value=80.0, allow_nan=False))
@settings(max_examples=300, deadline=None)
def test_decompose_reconstructs(x):
    """Property: 2^n · 2^(d/K) · e^(r·ln2/K) == e^x (decomposition law)."""
    n, d, r = jax.tree.map(np.asarray, decompose(jnp.float32(x)))
    recon = 2.0 ** (float(n) + (float(d) + float(r)) / K)
    assert np.isclose(recon, np.exp(x * np.log(2) / np.log(2)) ** 1.0,
                      rtol=1e-3) or np.isclose(
        np.log(recon), x, rtol=1e-3, atol=1e-3)
    assert 0 <= int(d) < K
    assert 0.0 <= float(r) <= 1.0 + 1e-5


@given(st.floats(min_value=-30.0, max_value=30.0, allow_nan=False),
       st.floats(min_value=-30.0, max_value=30.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_monotonicity(a, b):
    """Property: lut_exp preserves order (needed for a correct max trick)."""
    lo, hi = min(a, b), max(a, b)
    ya, yb = lut_exp(jnp.float32(lo)), lut_exp(jnp.float32(hi))
    assert float(ya) <= float(yb) * (1 + 1e-6)


def test_lut_exp2():
    x = jnp.linspace(-10, 10, 1001)
    np.testing.assert_allclose(np.asarray(lut_exp2(x)),
                               2.0 ** np.asarray(x), rtol=3e-5)


def test_grad_flows_through():
    g = jax.grad(lambda x: lut_exp(x))(1.0)
    assert np.isfinite(g) and abs(g - np.e) / np.e < 0.01
