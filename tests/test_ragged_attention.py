"""Varlen (ragged) paged attention: the packed-token-stream kernel proven
against BOTH oracles — the contiguous backends on the gathered view (per
lane, at each token's own causal bound) and the padded-paged chunk kernel
(the PR-3 step the ragged path replaces) — over ragged per-lane lengths,
GQA ratios, int8 pools and shuffled page tables; plus the ragged calling
convention through the attention-API registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.core.attention_api import (AttentionCall, attention,
                                      resolve_backend)
from repro.core.streaming_attention import quantize_kv_rows
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_varlen,
                                           paged_attention_varlen_reference,
                                           q_block_layout,
                                           validate_cu_seqlens,
                                           varlen_positions)


def make_pool(rng, n, hkv, ps, d):
    return (jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32)))


def gather_view(pool, tbl):
    """(N, Hkv, ps, D) + (S, P) → the contiguous (S, Hkv, P·ps, D) view the
    ragged path exists to avoid — used here only as the oracle input."""
    out = jnp.moveaxis(jnp.take(pool, tbl, axis=0), 1, 2)
    s = out.shape
    return out.reshape(s[0], s[1], s[2] * s[3], *s[4:])


def make_stream(rng, *, lanes, hq, d, ps, p, n):
    """A random packed stream: per-lane chunk lengths 1..4 at ragged live
    lengths, shuffled per-lane page tables → every varlen input array."""
    nq = rng.integers(1, 5, size=lanes)                   # chunk per lane
    lens = np.array([int(rng.integers(nq[i], p * ps + 1))
                     for i in range(lanes)])              # live after chunk
    cu = np.concatenate([[0], np.cumsum(nq)]).astype(np.int32)
    t = int(cu[-1])
    lane_tbl = np.stack([rng.permutation(n)[:p] for _ in range(lanes)])
    q = jnp.asarray(rng.normal(size=(t, hq, d)).astype(np.float32))
    q_pos = varlen_positions(cu, lens)
    token_tbl = lane_tbl[np.repeat(np.arange(lanes), nq)]  # (T, P)
    return q, jnp.asarray(token_tbl, jnp.int32), jnp.asarray(q_pos), \
        cu, jnp.asarray(lane_tbl, jnp.int32), lens, nq


def contiguous_oracle(backend, q, cu, lane_tbl, lens, kp, vp, **kw):
    """Per-lane contiguous attention on the gathered view: lane i's chunk
    rows at q_offset = len_i - nq_i — concatenated back into the stream."""
    kg, vg = gather_view(kp, lane_tbl), gather_view(vp, lane_tbl)
    outs = []
    for i in range(len(lens)):
        nq = int(cu[i + 1] - cu[i])
        li = int(lens[i])
        qi = jnp.moveaxis(q[cu[i]:cu[i + 1]], 0, 1)[None]  # (1, Hq, nq, D)
        o = attention(qi, kg[i:i + 1], vg[i:i + 1], backend=backend,
                      causal=True, q_offset=li - nq, kv_len=li,
                      exp_mode="lut", **kw)
        outs.append(np.moveaxis(np.asarray(o[0]), 0, 1))   # (nq, Hq, D)
    return np.concatenate(outs, axis=0)


def padded_paged_oracle(q, cu, lane_tbl, lens, kp, vp, **kw):
    """The PR-3 padded chunk kernel, lane by lane: q (1, Hq, nq, D) at
    kv_len = len_i through the lane's table row."""
    outs = []
    for i in range(len(lens)):
        qi = jnp.moveaxis(q[cu[i]:cu[i + 1]], 0, 1)[None]
        o = paged_attention(qi, kp, vp, lane_tbl[i:i + 1],
                            jnp.asarray([int(lens[i])], jnp.int32),
                            exp_mode="lut", **kw)
        outs.append(np.moveaxis(np.asarray(o[0]), 0, 1))
    return np.concatenate(outs, axis=0)


# ------------------------------------------------------------- equivalence --

@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4),              # GQA group size
       st.integers(1, 4),              # lanes packed into the stream
       st.sampled_from([4, 8, 16]),    # page size
       st.integers(2, 5),              # table width (pages per lane)
       st.integers(0, 10_000))         # seed
def test_varlen_matches_contiguous_backends(group, lanes, ps, p, seed):
    """Varlen reference == naive/jnp on the gathered view at every token's
    own causal bound, for shuffled tables, ragged lane lengths, ragged
    chunk lengths and every GQA packing."""
    rng = np.random.default_rng(seed)
    hkv, d = 2, 16
    hq = hkv * group
    n = p * lanes + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, lane_tbl, lens, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)

    got = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, cu_seqlens=cu, exp_mode="lut"))
    for backend in ("naive", "jnp"):
        want = contiguous_oracle(backend, q, cu, lane_tbl, lens, kp, vp)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4,
                                   err_msg=backend)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([4, 8]),
       st.integers(0, 10_000))
def test_varlen_matches_padded_paged_oracle(group, lanes, ps, seed):
    """Varlen == the padded-paged chunk kernel (the step it replaces) on
    the same pools/tables/positions — the flattening changes the batch
    layout, never a number."""
    rng = np.random.default_rng(seed)
    hkv, d, p = 2, 16, 3
    hq = hkv * group
    n = p * lanes + 2
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, lane_tbl, lens, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)

    got = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, exp_mode="lut"))
    want = padded_paged_oracle(q, cu, lane_tbl, lens, kp, vp)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([4, 8]), st.integers(0, 10_000))
def test_varlen_kernel_interpret_matches_reference(group, ps, seed):
    """The Pallas kernel (interpret mode, grid over tokens) == the jnp
    varlen reference."""
    rng = np.random.default_rng(seed)
    lanes, hkv, d, p = 3, 2, 16, 3
    n = p * lanes + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, _, _, _ = make_stream(
        rng, lanes=lanes, hq=hkv * group, d=d, ps=ps, p=p, n=n)

    ref = paged_attention_varlen_reference(q, kp, vp, token_tbl, q_pos,
                                           exp_mode="lut")
    ker = paged_attention_varlen(q, kp, vp, token_tbl, q_pos,
                                 exp_mode="lut", interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_varlen_int8_pool_close_to_float(rng):
    """INT8 pools (per-row scales, dequantised per page block) track the
    float varlen path within quantisation error, reference and kernel."""
    lanes, hq, hkv, d, ps, p = 3, 4, 2, 32, 8, 4
    n = p * lanes + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, lane_tbl, lens, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)

    def quant(pool):
        qv, s = quantize_kv_rows(pool.reshape(1, n * hkv, ps, d))
        return qv.reshape(n, hkv, ps, d), s.reshape(n, hkv, ps)

    kq, ks = quant(kp)
    vq, vs = quant(vp)
    want = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos))
    for impl in (paged_attention_varlen_reference,
                 lambda *a, **kw: paged_attention_varlen(*a, **kw,
                                                         interpret=True)):
        got = np.asarray(impl(q, kq, vq, token_tbl, q_pos,
                              k_scale=ks, v_scale=vs))
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        assert rel < 0.02, rel


def test_varlen_window_and_softcap(rng):
    """Sliding-window + logit-softcap masking agree with the naive oracle
    per token — local-attention layers ride the same packed stream."""
    lanes, hq, hkv, d, ps, p = 2, 4, 2, 16, 8, 4
    n = p * lanes
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, lane_tbl, lens, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)
    kw = dict(window=7, cap=15.0)

    got = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, **kw))
    want = contiguous_oracle("naive", q, cu, lane_tbl, lens, kp, vp, **kw)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_varlen_positions_helper():
    """varlen_positions: each lane segment ends at its live length − 1 —
    the packed restatement of the padded per-row bound kv_len − Lq + i."""
    cu = np.array([0, 3, 4, 8], np.int32)
    lens = np.array([10, 1, 6], np.int32)
    pos = varlen_positions(cu, lens)
    np.testing.assert_array_equal(pos, [7, 8, 9, 0, 2, 3, 4, 5])


def test_dead_rows_are_isolated(rng):
    """Bucket-padding rows (all-scratch table, q_pos 0) change nothing for
    live tokens and emit finite garbage themselves."""
    lanes, hq, hkv, d, ps, p = 2, 4, 2, 16, 8, 3
    n = p * lanes + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, _, _, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)
    t = q.shape[0]
    live = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos))

    pad = 3
    scratch = n - 1
    q2 = jnp.concatenate([q, jnp.asarray(
        rng.normal(size=(pad, hq, d)).astype(np.float32))])
    tbl2 = jnp.concatenate([token_tbl, jnp.full((pad, token_tbl.shape[1]),
                                                scratch, jnp.int32)])
    pos2 = jnp.concatenate([q_pos, jnp.zeros((pad,), jnp.int32)])
    both = np.asarray(paged_attention_varlen_reference(
        q2, kp, vp, tbl2, pos2))
    np.testing.assert_allclose(both[:t], live, atol=0, rtol=0)
    assert np.isfinite(both[t:]).all()


# ---------------------------------------------------------- q-block tiling --

def _decode_and_straddle_stream(rng, *, hq, hkv, d, ps, p, n):
    """A stream built to exercise the tiling edge cases: single-token decode
    lanes between prefill chunks, and chunk lengths chosen so lanes straddle
    q-block boundaries for every Bq in the test matrix."""
    nq = np.array([1, 5, 1, 7, 3])                        # decode + straddle
    lanes = len(nq)
    lens = np.array([int(rng.integers(nq[i], p * ps + 1))
                     for i in range(lanes)])
    cu = np.concatenate([[0], np.cumsum(nq)]).astype(np.int32)
    t = int(cu[-1])
    lane_tbl = np.stack([rng.permutation(n)[:p] for _ in range(lanes)])
    q = jnp.asarray(rng.normal(size=(t, hq, d)).astype(np.float32))
    q_pos = jnp.asarray(varlen_positions(cu, lens))
    token_tbl = jnp.asarray(lane_tbl[np.repeat(np.arange(lanes), nq)],
                            jnp.int32)
    return q, token_tbl, q_pos, cu


@pytest.mark.parametrize("block_q", [2, 3, 4, 8, 64])
@pytest.mark.parametrize("quant", [False, True])
def test_tiled_matches_untiled(rng, block_q, quant):
    """The q-block-tiled dataflow is a pure layout change: for every Bq
    (straddling lanes, single-token decode lanes, Bq > T) and both pool
    dtypes it reproduces the batch = T reference bit-for-bit-close —
    window + softcap riding along."""
    hq, hkv, d, ps, p = 4, 2, 16, 8, 3
    n = 16
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu = _decode_and_straddle_stream(
        rng, hq=hq, hkv=hkv, d=d, ps=ps, p=p, n=n)
    kw = dict(window=5, cap=20.0)
    if quant:
        def q8(pool):
            qv, s = quantize_kv_rows(pool.reshape(1, n * hkv, ps, d))
            return qv.reshape(n, hkv, ps, d), s.reshape(n, hkv, ps)
        kp, ks = q8(kp)
        vp, vs = q8(vp)
        kw.update(k_scale=ks, v_scale=vs)

    want = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, **kw))
    got = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, cu_seqlens=cu, block_q=block_q, **kw))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4),              # GQA group size
       st.integers(1, 4),              # lanes
       st.sampled_from([2, 3, 8]),     # Bq
       st.integers(0, 10_000))
def test_tiled_matches_contiguous_oracle(group, lanes, block_q, seed):
    """Tiled varlen == the contiguous per-lane oracle on random ragged
    streams (shuffled tables, ragged chunk and live lengths, every GQA
    packing) — the same bar the untiled path passes."""
    rng = np.random.default_rng(seed)
    hkv, d, ps, p = 2, 16, 4, 3
    hq = hkv * group
    n = p * lanes + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, lane_tbl, lens, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)

    got = np.asarray(paged_attention_varlen_reference(
        q, kp, vp, token_tbl, q_pos, cu_seqlens=cu, block_q=block_q,
        exp_mode="lut"))
    want = contiguous_oracle("jnp", q, cu, lane_tbl, lens, kp, vp)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_tiled_kernel_interpret_matches_reference(rng):
    """The Pallas kernel under q-block tiling (grid (q_block, kv_head,
    page_slot), interpret mode) == the untiled jnp reference."""
    hq, hkv, d, ps, p = 4, 2, 16, 8, 3
    n = 16
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu = _decode_and_straddle_stream(
        rng, hq=hq, hkv=hkv, d=d, ps=ps, p=p, n=n)

    ref = paged_attention_varlen_reference(q, kp, vp, token_tbl, q_pos)
    ker = paged_attention_varlen(q, kp, vp, token_tbl, q_pos,
                                 cu_seqlens=cu, block_q=8, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_tiled_dequant_page_matches_block(rng):
    """`dequant="page"` is the same numbers as `dequant="block"` — the knob
    changes the multiply granularity, never a value."""
    hq, hkv, d, ps, p = 4, 2, 16, 4, 4
    n = 16
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu = _decode_and_straddle_stream(
        rng, hq=hq, hkv=hkv, d=d, ps=ps, p=p, n=n)

    def q8(pool):
        qv, s = quantize_kv_rows(pool.reshape(1, n * hkv, ps, d))
        return qv.reshape(n, hkv, ps, d), s.reshape(n, hkv, ps)
    kq, ks = q8(kp)
    vq, vs = q8(vp)
    outs = [np.asarray(paged_attention_varlen_reference(
        q, kq, vq, token_tbl, q_pos, k_scale=ks, v_scale=vs,
        cu_seqlens=cu, block_q=4, block_pages=2, dequant=dq))
        for dq in ("block", "page")]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6, rtol=1e-6)
    with pytest.raises(ValueError, match="dequant"):
        paged_attention_varlen_reference(
            q, kq, vq, token_tbl, q_pos, k_scale=ks, v_scale=vs,
            dequant="nope")


def test_q_block_layout_roundtrip():
    """Layout invariants: every live block holds contiguous same-lane rows,
    kv_len puts kernel row i at the token's own position, and `slot` is the
    exact inverse map (gather(blocks)[slot] == identity on live tokens)."""
    cu = np.array([0, 1, 6, 7, 14, 17], np.int32)         # nq = 1,5,1,7,3
    lens = np.array([9, 5, 31, 12, 3])
    t, bq = int(cu[-1]), 4
    q_pos = jnp.asarray(varlen_positions(cu, lens))
    rows, start, kv_len, slot = map(np.asarray,
                                    q_block_layout(jnp.asarray(cu), q_pos,
                                                   t, bq))
    s = len(cu) - 1
    assert rows.shape == (t // bq + s, bq)
    live_blocks = int(sum(-(-int(n) // bq) for n in np.diff(cu)))
    # per-lane: blocks tile the segment in order, bq rows at a time
    b = 0
    for i in range(s):
        n = int(cu[i + 1] - cu[i])
        for j in range(-(-n // bq)):
            assert start[b] == cu[i] + j * bq
            want = np.clip(np.arange(start[b], start[b] + bq), 0, t - 1)
            np.testing.assert_array_equal(rows[b], want)
            assert kv_len[b] == int(q_pos[start[b]]) + bq
            b += 1
    assert b == live_blocks
    assert (kv_len[live_blocks:] == 1).all()              # dead blocks pinned
    # inverse map: scattering block-major data back is the identity
    flat = rows.reshape(-1)
    np.testing.assert_array_equal(flat[slot], np.arange(t))


def test_validate_cu_seqlens_raises():
    with pytest.raises(ValueError, match="start at 0"):
        validate_cu_seqlens(np.array([1, 4], np.int32), 4)
    with pytest.raises(ValueError, match="non-decreasing"):
        validate_cu_seqlens(np.array([0, 5, 3, 8], np.int32), 8)
    with pytest.raises(ValueError, match="pseudo-segment"):
        validate_cu_seqlens(np.array([0, 3, 6], np.int32), 8)
    with pytest.raises(ValueError, match="1-D"):
        validate_cu_seqlens(np.array([0], np.int32), 0)
    validate_cu_seqlens(np.array([0, 3, 8], np.int32), 8)  # ok
    # traced boundaries skip value checks (serving validates on the host
    # copy at pack time) but still trace through
    out = jax.jit(lambda c: validate_cu_seqlens(c, 8))(
        jnp.asarray([0, 3, 8], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), [0, 3, 8])


def _pool_gather_rows(jaxpr, pool_shape):
    """Total rows gathered from pool-shaped operands anywhere in the graph
    (scan bodies included) — the structural KV-traffic count."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "gather" and \
                tuple(eqn.invars[0].aval.shape) == pool_shape:
            total += int(np.prod(eqn.invars[1].aval.shape[:-1]))
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    total += _pool_gather_rows(v.jaxpr, pool_shape)
                elif isinstance(v, jax.core.Jaxpr):
                    total += _pool_gather_rows(v, pool_shape)
    return total


def test_tiled_page_gathers_scale_with_block_count(rng):
    """Structure, not timing: the traced tiled graph gathers KV pages
    O(T/Bq) times per page-block scan step where the untiled graph gathers
    O(T) — exactly proportional to the q-block count NB = T//Bq + S."""
    hq, hkv, d, ps, p = 4, 2, 16, 8, 3
    n, bq = 16, 8
    kp, vp = make_pool(rng, n, hkv, ps, d)
    nq = np.array([1, 13, 10])                            # T = 24
    lanes = len(nq)
    cu = np.concatenate([[0], np.cumsum(nq)]).astype(np.int32)
    t = int(cu[-1])
    lane_tbl = np.stack([rng.permutation(n)[:p] for _ in range(lanes)])
    token_tbl = jnp.asarray(lane_tbl[np.repeat(np.arange(lanes), nq)],
                            jnp.int32)
    q_pos = jnp.asarray(varlen_positions(
        cu, np.array([20, 13, 15])))
    q = jnp.asarray(rng.normal(size=(t, hq, d)).astype(np.float32))

    pool_shape = tuple(kp.shape)
    untiled = jax.make_jaxpr(lambda a: paged_attention_varlen_reference(
        a, kp, vp, token_tbl, q_pos))(q)
    tiled = jax.make_jaxpr(lambda a: paged_attention_varlen_reference(
        a, kp, vp, token_tbl, q_pos, cu_seqlens=cu, block_q=bq))(q)
    rows_u = _pool_gather_rows(untiled.jaxpr, pool_shape)
    rows_t = _pool_gather_rows(tiled.jaxpr, pool_shape)
    nb = t // bq + lanes                                  # 3 + 3
    assert rows_u > 0 and rows_t > 0
    assert rows_t < rows_u
    # exact proportionality: same scan skeleton, batch T vs batch NB
    assert rows_t * t == rows_u * nb, (rows_t, rows_u, t, nb)


# --------------------------------------------------------------- registry --

def _call(**kw):
    base = dict(lq=8, lkv=8, platform="cpu", static_lengths=False,
                has_kv_pos=False, inside_shard_map=False,
                has_page_table=True, is_ragged=True)
    base.update(kw)
    return AttentionCall(**base)


def test_resolution_ragged_calls_only_reach_paged_varlen():
    assert resolve_backend("auto", _call()).name == "paged_varlen"
    # the padded-paged backend and every contiguous backend refuse ragged
    for name in ("paged", "naive", "naive_decode", "jnp", "pallas"):
        with pytest.raises(ValueError, match="does not support"):
            resolve_backend(name, _call())
    # and the ragged backend refuses non-ragged calls
    for call in (_call(is_ragged=False),
                 _call(has_page_table=False, is_ragged=False)):
        with pytest.raises(ValueError, match="does not support"):
            resolve_backend("paged_varlen", call)
    # padded paged calls keep resolving to "paged", never the varlen path
    assert resolve_backend("auto", _call(is_ragged=False)).name == "paged"


def test_ragged_via_attention_api(rng):
    """attention(page_table=…, q_pos=…) resolves to paged_varlen and
    matches calling the varlen kernel module directly."""
    lanes, hq, hkv, d, ps, p = 2, 4, 2, 16, 8, 3
    n = 8
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q, token_tbl, q_pos, cu, _, _, _ = make_stream(
        rng, lanes=lanes, hq=hq, d=d, ps=ps, p=p, n=n)

    packed = jnp.moveaxis(q, 0, 1)[None]               # (1, Hq, T, D)
    via_api = attention(packed, kp, vp, backend="auto", causal=True,
                        page_table=token_tbl, q_pos=q_pos)
    direct = paged_attention_varlen(q, kp, vp, token_tbl, q_pos)
    np.testing.assert_allclose(
        np.asarray(via_api[0]), np.asarray(jnp.moveaxis(direct, 0, 1)),
        atol=0, rtol=0)
