"""INT8 quantisation substrate (paper §V) — properties and bounds."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st, hnp

from repro.core.quant import (QTensor, dense_maybe_quant, int8_matmul,
                              quantize, quantize_dynamic)


def test_roundtrip_error_bound(rng):
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    q = quantize(w, axis=0)
    err = np.abs(np.asarray(q.dequantize()) - np.asarray(w))
    # symmetric quantisation: |err| ≤ scale/2 per column
    bound = np.asarray(q.scale) / 2 + 1e-7
    assert (err <= bound).all()


def test_quantize_dtype_and_range(rng):
    q = quantize(jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)))
    assert q.values.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.values))) <= 127


@given(hnp.arrays(np.float32, (8, 16),
                  elements=st.floats(-100, 100, width=32)))
@settings(max_examples=100, deadline=None)
def test_scale_positive_and_error_bounded(w):
    q = quantize(jnp.asarray(w), axis=0)
    assert (np.asarray(q.scale) > 0).all()
    err = np.abs(np.asarray(q.dequantize()) - w)
    assert (err <= np.asarray(q.scale) / 2 + 1e-6).all()


def test_matmul_vs_float(rng):
    x = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    got = np.asarray(int8_matmul(x, quantize(w, axis=0)))
    rel = np.linalg.norm(got - np.asarray(x @ w)) / np.linalg.norm(x @ w)
    assert rel < 0.03


def test_dense_maybe_quant_dispatch(rng):
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    exact = np.asarray(dense_maybe_quant(x, w))
    q = np.asarray(dense_maybe_quant(x, quantize(w, axis=0)))
    forced = np.asarray(dense_maybe_quant(x, w, use_int8=True))
    np.testing.assert_allclose(exact, np.asarray(x @ w), atol=1e-5)
    np.testing.assert_allclose(q, forced, atol=1e-5)
    assert np.linalg.norm(q - exact) / np.linalg.norm(exact) < 0.05
