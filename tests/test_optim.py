"""Optimizer: AdamW math, schedules, clipping, accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, accumulated_grads, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule,
                         global_norm)


def test_adamw_first_step_matches_reference():
    """After one step with g, Adam moves by ≈ lr·g/|g| (bias-corrected)."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=None)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st = adamw_init(p, cfg)
    new_p, st, _ = adamw_update(g, st, p, cfg)
    # bias-corrected m̂ = g, v̂ = g² → delta = sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, -2.0 + 0.1], atol=1e-4)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=None)
    p = {"w": jnp.array([3.0, -4.0])}
    st = adamw_init(p, cfg)
    for _ in range(300):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, st, _ = adamw_update(g, st, p, cfg)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


def test_weight_decay_shrinks():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=None)
    p = {"w": jnp.array([10.0])}
    st = adamw_init(p, cfg)
    p2, _, _ = adamw_update({"w": jnp.zeros(1)}, st, p, cfg)
    assert float(p2["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0])


def test_cosine_schedule():
    s = cosine_schedule(1.0, warmup=10, total=110, final_frac=0.1)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(s(jnp.int32(110))) - 0.1) < 1e-6
    assert float(s(jnp.int32(60))) < 1.0


def test_scan_subtree_update_equivalent(rng):
    """Streaming the update over a stacked subtree must be bit-equivalent."""
    cfg = AdamWConfig(lr=0.01)
    p = {"trunk": {"periods": {"w": jnp.asarray(
        rng.normal(size=(4, 8)).astype(np.float32))}},
        "head": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    g = jax.tree.map(lambda x: x * 0.1, p)
    st = adamw_init(p, cfg)
    a, sa, _ = adamw_update(g, st, p, cfg)
    b, sb, _ = adamw_update(g, st, p, cfg, scan_subtree=("trunk", "periods"))
    np.testing.assert_allclose(np.asarray(a["trunk"]["periods"]["w"]),
                               np.asarray(b["trunk"]["periods"]["w"]),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(a["head"]), np.asarray(b["head"]))


def test_accumulation_equivalent_to_full_batch(rng):
    """mean-of-microbatch-grads == full-batch grad for a linear-in-batch loss."""
    w = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    batch = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))

    def loss_fn(p, b):
        return jnp.mean((b @ p["w"]) ** 2), {}

    l1, g1, _ = accumulated_grads(loss_fn, w, batch, 1)
    l4, g4, _ = accumulated_grads(loss_fn, w, batch, 4)
    assert abs(float(l1) - float(l4)) < 1e-6
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               atol=1e-6)


def test_accumulation_bf16_close(rng):
    w = {"w": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    batch = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))

    def loss_fn(p, b):
        return jnp.mean((b @ p["w"]) ** 2), {}

    _, g1, _ = accumulated_grads(loss_fn, w, batch, 1)
    _, gb, _ = accumulated_grads(loss_fn, w, batch, 4,
                                 accum_dtype="bfloat16")
    rel = (np.linalg.norm(np.asarray(gb["w"], np.float32) - np.asarray(g1["w"]))
           / np.linalg.norm(np.asarray(g1["w"])))
    assert rel < 0.02, rel
