"""INT8 KV-cache quantisation (beyond-paper serving optimisation, §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.streaming_attention import (quantize_kv_rows,
                                            streaming_attention,
                                            streaming_attention_quantized)
from repro.models import build_model


def test_quantize_kv_rows_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(2, 4, 16, 32)).astype(np.float32))
    q, s = quantize_kv_rows(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 4, 16)
    deq = q.astype(jnp.float32) * s[..., None]
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert (err <= np.asarray(s)[..., None] / 2 + 1e-6).all()


def test_quantized_attention_close_to_float(rng):
    q = jnp.asarray(rng.normal(size=(2, 4, 8, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 64, 32)).astype(np.float32))
    kq, ks = quantize_kv_rows(k)
    vq, vs = quantize_kv_rows(v)
    got = streaming_attention_quantized(q, kq, vq, ks, vs, causal=True,
                                        q_offset=56, block_k=16)
    want = streaming_attention(q, k, v, causal=True, q_offset=56, block_k=16)
    # int8 per-row quantisation: ~1% relative error on attention outputs
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02, rel


@pytest.mark.parametrize("name", [
    pytest.param("deepseek-7b", marks=pytest.mark.xfail(
        reason="genuine near-tie flip, not an argmax tie-break artefact "
               "(greedy ties break lowest-index since the serving "
               "tie-break landed): on this seed exactly one of 10 argmaxes "
               "(lane 0, step 4) has an f32 top-2 margin of 8.8e-3 while "
               "the int8 KV quantisation perturbs those logits by ~1.6e-2 "
               "— the flip (token 468 → 490) is below the quantisation "
               "noise floor, so exact greedy match is unattainable here",
        strict=False)),
    "gemma2-9b",
])
def test_greedy_decode_agrees(name, rng):
    """int8-KV decode must greedy-match the f32-KV path on smoke models."""
    cfg0 = get_config(name + "-smoke")
    m0 = build_model(cfg0)
    params = m0.init(jax.random.PRNGKey(0))
    mq = build_model(cfg0.replace(kv_quant=True))
    B, L, EXTRA = 2, 12, 5
    toks = jnp.asarray(rng.integers(0, cfg0.vocab_size, (B, L + EXTRA)),
                       jnp.int32)

    def run(m):
        caches = m.init_cache(B, L + EXTRA)
        lg, st = m.prefill(params, {"tokens": toks[:, :L]}, caches)
        outs = []
        for t in range(EXTRA):
            lg, st = m.decode_step(params, toks[:, L + t], st,
                                   jnp.int32(L + t))
            outs.append(lg)
        return jnp.stack(outs, 1)

    d0, dq = run(m0), run(mq)
    agree = float(jnp.mean((jnp.argmax(d0, -1) == jnp.argmax(dq, -1)
                            ).astype(jnp.float32)))
    assert agree == 1.0, agree


def test_quantized_cache_is_int8():
    cfg = get_config("deepseek-7b-smoke").replace(kv_quant=True)
    m = build_model(cfg)
    caches = m.init_cache(2, 32)
    leaves = {p[-1].key: l for p, l
              in jax.tree_util.tree_flatten_with_path(caches)[0]}
    assert leaves["k"].dtype == jnp.int8
    assert leaves["ks"].dtype == jnp.float32
    # 2 bytes/elem (bf16) → 1 byte + 4/D scale overhead.  The smoke config's
    # tiny head_dim (16) makes the overhead 25%; production head dims
    # (128–256) land at ~51.5% of bf16.
    kv_bytes = leaves["k"].size + 4 * leaves["ks"].size
    bf16_bytes = 2 * leaves["k"].size
    assert kv_bytes < 0.7 * bf16_bytes
