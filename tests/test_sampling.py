"""In-step per-request sampling: semantics, batch invariance, trace shape.

The PR-8 contracts:

- validation — unservable params (max_new ≤ 0, negative temperature with a
  seed, top-k ≤ 0, stop tokens outside the vocab, …) raise
  ``InvalidRequest`` at construction/submit, never mid-serve;
- greedy identity — temperature 0 through the in-step sampler is
  bit-identical to the host lowest-index tie-break, so the full
  cross-engine equivalence matrix (float + int8 × spec × prefix-cache)
  is unchanged;
- batch invariance — a request's sampled stream is a pure function of
  (seed, params, prompt): identical whether served alone, co-batched with
  other traffic, or preempted and replayed;
- stop sequences — truncation lands at exactly the completing token, even
  mid-way through a multi-token speculative commit, and never leaks the
  match into the output;
- trace stability — all sampling params are data: serving new
  temperatures/seeds/top-k/top-p retraces nothing (O(1) compiles);
- graph shape — sampling runs INSIDE the jitted ragged step: the traced
  step outputs int32 tokens (no (lanes, V) float output, no host
  round-trip between logits and token) and its one sampling region
  operates on last-idx-gathered rows, never on the full (T, V) stream.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (EngineCore, InvalidRequest, Request,
                           SamplingParams, ServingEngine)
from repro.serving.sampling import greedy_rows, sample_rows, stop_holdback
from tests.test_engine_core import _sampling_args, build, by_uid, prompts_for


def engine(cfg, params, **kw):
    kw.setdefault("lanes", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("chunk_size", 8)
    return EngineCore(cfg, params, **kw)


def serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    return by_uid(eng.run())


# ------------------------------------------------------------- validation --

def test_invalid_params_rejected_at_construction():
    with pytest.raises(InvalidRequest, match="temperature"):
        SamplingParams(temperature=-0.5, seed=3)
    with pytest.raises(InvalidRequest, match="top_k"):
        SamplingParams(top_k=0)
    with pytest.raises(InvalidRequest, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(InvalidRequest, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(InvalidRequest, match="seed"):
        SamplingParams(seed=2 ** 32)
    with pytest.raises(InvalidRequest, match="max_tokens"):
        SamplingParams(max_tokens=0)
    with pytest.raises(InvalidRequest, match="stop"):
        SamplingParams(stop=((),))          # empty stop sequence
    with pytest.raises(InvalidRequest, match="stop"):
        SamplingParams(stop=((-3,),))       # negative token id
    # negative temperature WITHOUT a seed is just greedy — servable
    assert SamplingParams(temperature=-1.0).greedy


def test_invalid_requests_rejected_at_submit():
    cfg, params = build()
    eng = engine(cfg, params)
    p = prompts_for(cfg, 0, (8,))[0]
    with pytest.raises(InvalidRequest, match="max_new"):
        Request(uid=0, prompt=p, max_new=0)
    with pytest.raises(InvalidRequest, match="max_tokens"):
        Request(uid=0, prompt=p, max_new=4,
                sampling=SamplingParams(max_tokens=-1))
    # stop tokens outside the vocab: only the engine knows the vocab
    bad = Request(uid=1, prompt=p, max_new=4,
                  sampling=SamplingParams(stop=((cfg.vocab_size,),)))
    with pytest.raises(InvalidRequest, match="vocab"):
        eng.submit(bad)
    assert not eng.scheduler.has_work()     # nothing half-admitted
    # the slot engine rejects the same way
    slot = ServingEngine(cfg, params, slots=1, max_len=48)
    with pytest.raises(InvalidRequest, match="vocab"):
        slot.submit(bad)


def test_max_tokens_folds_into_max_new():
    cfg, params = build()
    p = prompts_for(cfg, 0, (8,))[0]
    r = Request(uid=0, prompt=p, max_new=16,
                sampling=SamplingParams(max_tokens=3))
    assert r.max_new == 3
    assert serve(engine(cfg, params), [r])[0] == r.tokens
    assert len(r.tokens) == 3


# -------------------------------------------------------- greedy identity --

def test_in_step_greedy_matches_host_tie_break():
    """Crafted exact ties: the in-step greedy pick is the host
    lowest-index rule, row for row."""
    from repro.serving.core import greedy_tokens
    rng = np.random.default_rng(0)
    lg = rng.normal(size=(6, 33)).astype(np.float32)
    lg[0, 4] = lg[0, 19] = lg[0].max() + 1.0        # two joint maxima
    lg[1, :] = 0.0                                  # all tied → index 0
    lg[2, 32] = lg[2].max() + 1.0                   # winner at the edge
    z = np.zeros((6,), np.int32)
    picks = np.asarray(sample_rows(
        lg, np.zeros((6,), np.float32), z, np.ones((6,), np.float32),
        z.astype(np.uint32), z))
    assert (picks == greedy_tokens(lg)).all()
    assert picks[0] == 4 and picks[1] == 0 and picks[2] == 32
    assert (np.asarray(greedy_rows(lg)) == picks).all()


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("feature", ["plain", "spec", "prefix"])
def test_temperature_zero_identity_across_matrix(kv_quant, feature):
    """temperature=0 through the in-step sampler reproduces the padded
    oracle's host-greedy streams across float + int8 × speculative ×
    prefix-cache — the pre-existing equivalence matrix survives the
    sampler moving into the graph."""
    cfg, params = build(kv_quant=kv_quant)
    lens, news = (3, 21, 9, 14), (7, 5, 9, 4)
    kw = {"speculative": feature == "spec",
          "prefix_cache": feature == "prefix"}

    def reqs():
        return [Request(uid=i, prompt=p, max_new=news[i])
                for i, p in enumerate(prompts_for(cfg, 13, lens))]

    ragged = serve(engine(cfg, params, **kw), reqs())
    oracle = serve(engine(cfg, params, mode="padded"), reqs())
    assert ragged == oracle


# -------------------------------------------------------- batch invariance --

def _solo_stream(cfg, params, req_fn, **kw):
    eng = engine(cfg, params, **kw)
    return serve(eng, [req_fn()])[100]


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_sampled_stream_batch_invariant(kv_quant, prefix_cache):
    """Same (seed, prompt, params) → the same token stream whether the
    request runs alone or shares its steps with co-batched traffic that
    lands it on a different lane."""
    cfg, params = build(kv_quant=kv_quant)
    others = prompts_for(cfg, 7, (13, 7, 21))
    mine = prompts_for(cfg, 8, (5,))[0]

    def req():
        return Request(uid=100, prompt=mine, max_new=6,
                       sampling=SamplingParams(temperature=0.8, top_k=50,
                                               top_p=0.95, seed=42))

    alone = _solo_stream(cfg, params, req, prefix_cache=prefix_cache)
    eng = engine(cfg, params, prefix_cache=prefix_cache)
    crowd = [Request(uid=i, prompt=p, max_new=6)
             for i, p in enumerate(others)]
    shared = serve(eng, crowd + [req()])
    assert shared[100] == alone
    for i in range(3):                      # greedy neighbours unperturbed
        assert shared[i] == serve(engine(cfg, params),
                                  [Request(uid=i, prompt=others[i],
                                           max_new=6)])[i]


def test_sampled_stream_survives_preemption_replay():
    """Per-request keys make even temperature > 0 preemption-deterministic:
    a sampled request evicted mid-flight replays to the identical stream
    (the old shared-PRNG engine could not promise this)."""
    cfg, params = build()
    lens = (17, 15, 13, 11)
    sp = lambda: SamplingParams(temperature=0.9, seed=5)   # noqa: E731

    def reqs():
        rs = [Request(uid=i, prompt=p, max_new=6, sampling=sp())
              for i, p in enumerate(prompts_for(cfg, 3, lens))]
        return rs

    roomy = serve(engine(cfg, params, num_pages=64), reqs())
    tight_eng = engine(cfg, params, num_pages=14, lanes=4)
    tight = serve(tight_eng, reqs())
    assert tight_eng.scheduler.preempted_count > 0, (
        "pool never pressured — preemption path not exercised")
    assert tight == roomy


def test_seeded_streams_reproducible_and_seed_dependent():
    cfg, params = build()
    p = prompts_for(cfg, 1, (9,))[0]

    def stream(seed):
        return serve(engine(cfg, params),
                     [Request(uid=100, prompt=p, max_new=8,
                              sampling=SamplingParams(temperature=1.2,
                                                      seed=seed))])[100]

    assert stream(7) == stream(7)
    assert stream(7) != stream(8)           # overwhelmingly likely


def test_slot_engine_oracle_shares_sampling_semantics():
    """The slot engine draws through the same single-lane oracle kernel:
    same (seed, params, prompt) → same stream as EngineCore on a
    single-request trace (logits match exactly at lanes=1)."""
    cfg, params = build()
    p = prompts_for(cfg, 2, (8,))[0]
    sp = SamplingParams(temperature=1.0, seed=11)
    core = serve(engine(cfg, params, lanes=1),
                 [Request(uid=0, prompt=p, max_new=6, sampling=sp)])[0]
    slot = ServingEngine(cfg, params, slots=1, max_len=48)
    slot.submit(Request(uid=0, prompt=p, max_new=6, sampling=sp))
    assert slot.run()[0].tokens == core


# ------------------------------------------------------- degenerate params --

def _rows(seed=0, n=16, v=33):
    """Random rows plus the adversarial shapes: exact ties, a flat row, a
    one-token-dominant row (cumsum rounding pressure), NEG_INF-ish tails."""
    rng = np.random.default_rng(seed)
    lg = rng.normal(size=(n, v)).astype(np.float32)
    lg[0, :] = 0.0                                  # all tied
    lg[1, 5] = lg[1, 20] = lg[1].max() + 1.0        # joint maxima
    lg[2, 7] += 40.0                                # ~all mass on one token
    lg[3, :10] = -1e30                              # hard-masked head
    return lg


def _picks(lg, *, temps, top_k=0, top_p=1.0, seed=0):
    n, v = lg.shape
    return np.asarray(sample_rows(
        lg, np.full((n,), temps, np.float32),
        np.full((n,), top_k, np.int32), np.full((n,), top_p, np.float32),
        np.full((n,), seed, np.uint32), np.arange(n, dtype=np.int32)))


@pytest.mark.parametrize("top_k", [33, 40])      # k == V and k > V
def test_top_k_at_least_vocab_is_bit_identical_to_no_mask(top_k):
    """k ≥ V keeps the k-th-largest threshold at the row minimum, so the
    mask keeps every token: the drawn stream is BIT-identical to top_k
    disabled on the same seeds — exactly no-op, not almost-surely."""
    lg = _rows()
    for seed in (0, 3, 11, 2 ** 31):
        for temps in (0.7, 1.3):
            a = _picks(lg, temps=temps, top_k=top_k, seed=seed)
            b = _picks(lg, temps=temps, top_k=0, seed=seed)
            assert (a == b).all(), (top_k, seed, temps, a, b)


def test_top_p_one_keeps_the_whole_vocabulary():
    """p == 1.0 disables the nucleus mask *explicitly*: the cumulative
    sum's float rounding may touch 1.0 before the last sorted token (the
    dominant-token and hard-masked rows above push it there), and the
    mass-comparison alone would then drop positive-probability tail
    tokens.  The engine encodes top_p=None as 1.0, so the explicit-1.0
    request must ride the identical pipeline bit for bit."""
    lg = _rows()
    for seed in (0, 7, 123):
        a = _picks(lg, temps=1.1, top_p=1.0, seed=seed)
        b = _picks(lg, temps=1.1, top_p=np.float32(1.0), seed=seed)
        assert (a == b).all()
        assert ((0 <= a) & (a < lg.shape[1])).all()


@pytest.mark.parametrize("tiny", [1e-30, 1e-8, 1e-4])
def test_tiny_temperature_stays_finite_and_greedy_in_the_limit(tiny):
    """temperature → 0+ must not overflow: raw logits / t reaches ±inf at
    t = 1e-30 and a non-finite score poisons ``lut_log_softmax`` (NaN
    scores argmax to index 0, silently).  The max-shift keeps scaled
    scores in [-big, 0], so the draw is finite and — with the winner's
    scaled gap astronomically larger than any Gumbel noise — lands on the
    greedy token, which is NOT index 0 in these rows."""
    lg = _rows()
    want = np.asarray(greedy_rows(lg))
    assert (want[1:4] != 0).any()
    for seed in (0, 5, 99):
        got = _picks(lg, temps=tiny, seed=seed)
        # ties (rows 0–1) may legitimately break off-index under noise at
        # the larger tiny temps; the non-tied rows must be exactly greedy
        assert (got[2:] == want[2:]).all(), (tiny, seed, got, want)


@pytest.mark.parametrize("edge", ["top_k_full", "top_k_over", "top_p_one"])
def test_degenerate_mask_params_noop_end_to_end(edge):
    """Engine-level contract: an explicit top_k ≥ vocab or top_p = 1.0 in
    SamplingParams serves the same stream as the plain temperature-only
    request — the knobs are exact no-ops all the way through submit."""
    cfg, params = build()
    kw = {"top_k_full": dict(top_k=cfg.vocab_size),
          "top_k_over": dict(top_k=cfg.vocab_size + 9),
          "top_p_one": dict(top_p=1.0)}[edge]
    p = prompts_for(cfg, 6, (9,))[0]

    def stream(extra):
        return serve(engine(cfg, params),
                     [Request(uid=0, prompt=p, max_new=6,
                              sampling=SamplingParams(temperature=0.9,
                                                      seed=17, **extra))])[0]

    assert stream(kw) == stream({})


# ---------------------------------------------------------- stop sequences --

def _greedy_stream(cfg, params, prompt, max_new, **kw):
    return serve(engine(cfg, params, **kw),
                 [Request(uid=0, prompt=prompt, max_new=max_new)])[0]


def test_stop_sequence_truncates_and_finishes():
    cfg, params = build()
    p = prompts_for(cfg, 4, (9,))[0]
    g = _greedy_stream(cfg, params, p, 6)
    eng = engine(cfg, params)
    out = serve(eng, [Request(uid=0, prompt=p, max_new=6,
                              sampling=SamplingParams(
                                  stop=((g[2], g[3]),)))])[0]
    assert out == g[:2]                     # match excluded from output
    assert eng.pages_in_use == 0            # finished → pages released


def test_stop_sequence_across_step_boundary():
    """A stop sequence whose tokens commit in different steps (decode is
    one token per step) still truncates at the match start — tokens from
    the earlier step are retracted from the output."""
    cfg, params = build()
    p = prompts_for(cfg, 4, (9,))[0]
    g = _greedy_stream(cfg, params, p, 6)
    out = serve(engine(cfg, params),
                [Request(uid=0, prompt=p, max_new=6,
                         sampling=SamplingParams(
                             stop=((g[1], g[2], g[3]),)))])[0]
    assert out == g[:1]


def test_stop_sequence_mid_speculative_commit():
    """A drafting lane can commit several tokens in one step; a stop
    completing inside the commit truncates exactly there and rolls the
    pool back clean."""
    cfg, params = build()
    pat = np.array([7, 8, 9, 7, 8, 9, 7, 8, 9, 7, 8], np.int32)
    g = _greedy_stream(cfg, params, pat, 8, speculative=True, spec_k=3)
    eng = engine(cfg, params, speculative=True, spec_k=3)
    out = serve(eng, [Request(uid=0, prompt=pat, max_new=8,
                              sampling=SamplingParams(
                                  stop=((g[2], g[3]),)))])[0]
    assert out == g[:2]
    assert eng.pages_in_use == 0


def test_stop_holdback_never_streams_a_retracted_token():
    stops = ((5, 6, 7), (9,))
    # suffix [5, 6] is a proper stop prefix → held back
    assert stop_holdback([1, 5, 6], stops) == 1
    # completing the stop is the engine's job (truncation), not holdback's
    assert stop_holdback([1, 2, 3], stops) == 3
    # single-token stops hold nothing (a hit truncates before reporting)
    assert stop_holdback([1, 2], ((9,),)) == 2


# ----------------------------------------------------------- trace shape --

def test_sampling_params_are_data_O1_compiles():
    """Serving a second wave with entirely new sampling params (new
    temperatures, seeds, top-k/top-p) retraces nothing: the params ride
    the jitted step as arrays, never as static args."""
    cfg, params = build()
    eng = engine(cfg, params)

    def wave(seed, temps):
        rs = [Request(uid=seed * 100 + i, prompt=p, max_new=4,
                      sampling=SamplingParams(
                          temperature=t,
                          top_k=None if t == 0 else 20 + seed,
                          top_p=None if t == 0 else 0.8 + 0.01 * seed,
                          seed=None if t == 0 else seed * 7 + i))
              for i, (p, t) in enumerate(
                  zip(prompts_for(cfg, seed, (5, 9, 13, 7)), temps))]
        serve(eng, rs)

    wave(1, (0.0, 0.7, 1.3, 0.0))
    traced = eng.trace_count
    assert traced > 0
    wave(2, (1.1, 0.0, 0.5, 2.0))           # all-new params, same shapes
    assert eng.trace_count == traced, (
        f"sampling params retraced the step: {traced} → {eng.trace_count}")


def test_sampling_runs_inside_ragged_step_jaxpr():
    """Walk the traced ragged step: (1) it OUTPUTS int32 tokens — no
    (lanes, V) float logits ever leave the graph, so there is no host
    round-trip between logits and token; (2) the sampling region (the
    sort-based top-k/top-p masks) operates on the (lanes, V) last-idx
    gather only — never on a (T, V) full-stream tensor."""
    from tests.test_paged_serving import _jaxpr_shapes

    cfg, params = build()
    lanes, t, pw = 3, 48, 4
    eng = engine(cfg, params, lanes=lanes, page_size=8, chunk_size=24,
                 num_pages=32)
    cu = jnp.asarray([0, 1, 2, t, t], jnp.int32)
    jaxpr = jax.make_jaxpr(eng._ragged)(
        eng.params, eng.kv.pool, jnp.full((t, pw), eng.kv.scratch, jnp.int32),
        jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
        jnp.zeros((lanes,), jnp.int32), cu, *_sampling_args(lanes))

    v = cfg.vocab_size
    outs = [(o.aval.shape, o.aval.dtype) for o in jaxpr.jaxpr.outvars]
    assert (outs[0] == ((lanes,), jnp.int32)), outs[0]
    assert all(s != (lanes, v) for s, _ in outs), (
        "step leaks (lanes, V) logits to the host")

    # sampling region shape: every sort in the graph runs on the
    # (lanes, V) gathered rows — none on the (T, V) packed stream
    def sorts(jx, acc):
        for eqn in jx.eqns:
            if eqn.primitive.name == "sort":
                acc.append(tuple(eqn.invars[0].aval.shape))
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (list, tuple)) else [val]):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        sorts(sub.jaxpr, acc)
                    elif isinstance(sub, jax.core.Jaxpr):
                        sorts(sub, acc)
        return acc

    seen = sorts(jaxpr.jaxpr, [])
    assert seen, "sampling region not found in the traced step"
    assert set(seen) == {(lanes, v)}, seen
    assert all(s[0] != t for s in seen)
    # and no (T, V) tensor exists anywhere (logits stay last-idx-gathered)
    assert all(s[-2:] != (t, v) for s in _jaxpr_shapes(jaxpr.jaxpr)
               if len(s) >= 2)
