"""Fault-tolerant runtime: failure injection, bit-exact recovery, resume,
straggler handling, grad compression."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.runtime import FailureInjector, TrainConfig, Trainer


def mk_trainer(tmp_path, **kw):
    cfg = get_config("deepseek-7b-smoke")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, global_batch=4,
                      corpus="lm")
    base = dict(steps=8, ckpt_dir=str(tmp_path), ckpt_every=3, lr=5e-3,
                warmup=2)
    base.update(kw)
    return Trainer(cfg, dcfg, TrainConfig(**base))


def test_loss_decreases(tmp_path):
    tr = mk_trainer(tmp_path, steps=10)
    m = tr.run()
    assert m[-1]["loss"] < m[0]["loss"]


def test_failure_recovery_bitexact(tmp_path):
    ma = mk_trainer(tmp_path / "a").run()
    mb = mk_trainer(tmp_path / "b").run(
        injector=FailureInjector(fail_at_steps=(4, 6)))
    la = {m["step"]: m["loss"] for m in ma}
    lb = {m["step"]: m["loss"] for m in mb}
    assert max(abs(la[s] - lb[s]) for s in la) == 0.0


def test_auto_resume_from_checkpoint(tmp_path):
    tr1 = mk_trainer(tmp_path, steps=6)
    tr1.run()
    # a fresh Trainer on the same dir resumes at the saved step
    tr2 = mk_trainer(tmp_path, steps=10)
    m = tr2.run()
    assert tr2.step == 10
    assert m[0]["step"] >= 6


def test_unrecoverable_without_ckpt(tmp_path):
    tr = mk_trainer(tmp_path, ckpt_dir=None)
    with pytest.raises(Exception):
        tr.run(injector=FailureInjector(fail_at_steps=(2,)))


def test_straggler_logging(tmp_path):
    tr = mk_trainer(tmp_path, steps=4, straggler_timeout_ms=0.0001,
                    skip_straggler_steps=False)
    tr.run()
    assert len(tr.straggler_log) > 0      # every CPU step exceeds 0.1 µs


def test_compressed_grads_still_learn(tmp_path):
    tr = mk_trainer(tmp_path, steps=10, compress_grads=True)
    m = tr.run()
    assert m[-1]["loss"] < m[0]["loss"]
    assert tr.residual is not None        # error-feedback state exists


def test_error_feedback_accumulates():
    import jax.numpy as jnp
    from repro.parallel import compress_with_feedback, feedback_init
    g = {"w": jnp.full((4,), 1e-4, jnp.float32)}   # below bf16 resolution of 1.0
    r = feedback_init(g)
    total = jnp.zeros((4,))
    for _ in range(50):
        sent, r = compress_with_feedback(g, r)
        total = total + sent["w"].astype(jnp.float32)
    # over many steps the *sum* of sent gradients matches the true sum
    np.testing.assert_allclose(np.asarray(total), 50e-4, rtol=0.05)
