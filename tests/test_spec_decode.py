"""Speculative decoding: the draft/verify/rollback gate suite.

The contract under test is exactness, not speed: greedy speculative decode
must be **token-identical** to non-speculative decode — the verify rule is
argmax equality against the engine's own greedy pick, so a drafted token is
committed iff sequential decode would have emitted it — and a rejected
draft must leave **no trace in the pool**: refcounts, free heap, page
tables and cursors identical to never having drafted.  Covered here:

- equivalence cross: speculative ragged decode vs non-speculative
  ragged *and* padded baselines, float and int8, k ∈ {1, 2, 4}, prefix
  cache on and off, under a proposer that mixes full accepts, partial
  accepts and full rejects;
- forced best case (oracle proposer replaying the true continuation: every
  draft accepted, strictly fewer steps) and forced worst case (adversarial
  proposer off-by-one everywhere: every draft rejected, stream unchanged);
- acceptance-rule property: each drafting step commits exactly the longest
  drafted prefix matching the true continuation, plus the bonus token;
- pool-state twin: stepping a drafting engine whose every draft is
  rejected leaves refcounts / free heap / tables / cursors equal to a
  never-drafting twin after *every* step;
- scheduler properties with 1+k decode chunks: packing invariants (budget,
  tightest bucket, cu_seqlens/pos/stream consistency) hold with drafts in
  the stream and under preemption; a budget-starved step sheds drafts —
  never mandatory tokens, never residents; page pressure degrades drafts
  without evicting anyone;
- compile-level gates: the verify step's graph is the same one-varlen-
  attend graph as the plain ragged step (no per-draft loop, no gathered
  (lanes, k) KV), and k is a static shape — draft counts varying 0..k
  retrace nothing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.serving import (EngineCore, NGramProposer, Request, Scheduler,
                           StepOutput)
from tests.test_engine_core import build, by_uid, prompts_for

LANES, PS, PAGES, CHUNK, MAX_NEW = 2, 8, 32, 8, 8


def _prompts(cfg, n=4, shared=2 * PS, tail=4, seed=11):
    """n equal-length prompts sharing a page-aligned prefix (so the prefix
    cache has something to hit) with distinct tails (so the scripted
    proposers can tell the streams apart)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size,
                                         tail).astype(np.int32)])
            for _ in range(n)]


def _serve(eng, prompts, max_new=MAX_NEW):
    """Submit one request per prompt and drain → (uid → tokens, n_steps)."""
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=max_new))
    steps = 0
    while eng.scheduler.has_work():
        eng.step()
        steps += 1
        assert steps < 10_000
    return by_uid(eng.finished), steps


class ScriptedProposer:
    """Drafts by replaying a known ground-truth generation.

    ``truth`` maps each prompt (as a tuple) to its greedy continuation.
    ``corrupt(call_index, k)`` returns the draft position to corrupt
    (off-by-one the token) or None — so tests can force full acceptance
    (never corrupt), full rejection (always position 0) or exact partial
    acceptance.  Streams are matched on the full prompt (all prompts are
    equal length), so shared prefixes never alias.
    """

    def __init__(self, truth, vocab, corrupt=lambda i, k: None):
        self.truth = {tuple(p): list(t) for p, t in truth.items()}
        self.vocab = vocab
        self.corrupt = corrupt
        self.calls = 0
        self.log = []                       # (drafts, true continuation)

    def __call__(self, stream, k):
        s = [int(t) for t in stream]
        for prompt, toks in self.truth.items():
            lp = len(prompt)
            if tuple(s[:lp]) == prompt and s[lp:] == toks[:len(s) - lp]:
                got = len(s) - lp
                cont = toks[got:got + k]
                drafts = list(cont)
                m = self.corrupt(self.calls, len(drafts))
                if m is not None and m < len(drafts):
                    drafts[m] = (drafts[m] + 1) % self.vocab
                self.calls += 1
                if drafts:
                    self.log.append((drafts, cont))
                return drafts
        return []


_BASE = {}       # (kv_quant, mode) → (cfg, params, uid → tokens, steps)


def _baseline(kv_quant, mode):
    if (kv_quant, mode) not in _BASE:
        cfg, params = build(kv_quant=kv_quant)
        eng = EngineCore(cfg, params, lanes=LANES, page_size=PS,
                         num_pages=PAGES, chunk_size=CHUNK, mode=mode)
        done, steps = _serve(eng, _prompts(cfg))
        assert eng.pages_in_use == 0
        _BASE[(kv_quant, mode)] = (cfg, params, done, steps)
    return _BASE[(kv_quant, mode)]


def _truth(cfg, done):
    return {tuple(int(t) for t in p): done[i]
            for i, p in enumerate(_prompts(cfg))}


def _spec_engine(cfg, params, proposer, k, prefix_cache=False, lanes=LANES,
                 num_pages=PAGES, **kw):
    return EngineCore(cfg, params, lanes=lanes, page_size=PS,
                      num_pages=num_pages, chunk_size=CHUNK, mode="ragged",
                      speculative=True, spec_k=k, proposer=proposer,
                      prefix_cache=prefix_cache, **kw)


# ------------------------------------------------------ equivalence cross --

_SPEC = {}       # (kv_quant, k, prefix_cache) → (uid → tokens, stats)


def _spec_run(kv_quant, k, prefix_cache):
    """Memoized speculative run under the mixed-corruption proposer: the
    corrupt position cycles ∅, 0, 1, … so full accepts, full rejects and
    partial accepts (rollback) all happen in every configuration."""
    key = (kv_quant, k, prefix_cache)
    if key not in _SPEC:
        cfg, params, want, _ = _baseline(kv_quant, "ragged")
        prop = ScriptedProposer(
            _truth(cfg, want), cfg.vocab_size,
            corrupt=lambda i, d: None if i % (k + 1) == 0
            else i % (k + 1) - 1)
        eng = _spec_engine(cfg, params, prop, k, prefix_cache)
        done, _ = _serve(eng, _prompts(cfg))
        # with the cache on, published prefix pages deliberately stay
        # resident after finish; everything else must be back in the heap
        cached = eng.prefix_stats.get("cached_pages", 0) if prefix_cache else 0
        assert eng.pages_in_use == cached
        assert eng.drafted_total > 0, "proposer never drafted"
        _SPEC[key] = (done, eng.spec_stats)
    return _SPEC[key]


@pytest.mark.parametrize("prefix_cache", [False, True])
@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("base_mode", ["ragged", "padded"])
@pytest.mark.parametrize("kv_quant", [False, True])
def test_spec_greedy_token_identical(kv_quant, base_mode, k, prefix_cache):
    """Speculative greedy decode emits byte-identical token streams to the
    non-speculative engine in BOTH baseline packings, float and int8,
    k ∈ {1,2,4}, prefix cache on and off — under a proposer that mixes
    full accepts, partial accepts and full rejects."""
    _, _, want, _ = _baseline(kv_quant, base_mode)
    done, stats = _spec_run(kv_quant, k, prefix_cache)
    assert done == want, (
        f"speculative (k={k}, cache={prefix_cache}) diverged from "
        f"{base_mode} baseline: {stats}")


def test_spec_partial_acceptance_actually_happened():
    """The cross above must have exercised rollback, not just all-or-
    nothing: at k=4 the corruption cycle yields partial accepts (0 <
    acceptance < 1)."""
    _, stats = _spec_run(False, 4, False)
    assert 0.0 < stats["acceptance"] < 1.0, stats


# --------------------------------------------------- forced best and worst --

def test_spec_best_case_all_accepted_fewer_steps():
    """Oracle proposer replays the true continuation: every draft accepted
    (acceptance = 1), the stream is identical, and the engine takes
    strictly fewer steps than sequential decode."""
    cfg, params, want, base_steps = _baseline(False, "ragged")
    prop = ScriptedProposer(_truth(cfg, want), cfg.vocab_size)
    eng = _spec_engine(cfg, params, prop, k=4)
    done, steps = _serve(eng, _prompts(cfg))
    assert done == want
    s = eng.spec_stats
    assert s["acceptance"] == 1.0 and s["drafted_tokens"] > 0, s
    assert steps < base_steps, (steps, base_steps)
    assert eng.pages_in_use == 0


def test_spec_worst_case_all_rejected_stream_unchanged():
    """Adversarial proposer corrupts draft position 0 every call: every
    draft is rejected, yet the stream is identical and the pool drains
    clean — speculation can waste work but never corrupt state."""
    cfg, params, want, _ = _baseline(False, "ragged")
    prop = ScriptedProposer(_truth(cfg, want), cfg.vocab_size,
                            corrupt=lambda i, d: 0)
    eng = _spec_engine(cfg, params, prop, k=4)
    done, _ = _serve(eng, _prompts(cfg))
    assert done == want
    s = eng.spec_stats
    assert s["drafted_tokens"] > 0 and s["accepted_tokens"] == 0, s
    assert eng.pages_in_use == 0


def test_ngram_proposer_end_to_end():
    """The default n-gram proposer (no scripting, no ground truth) is also
    token-identical — lookup drafts are just another proposer under the
    same verify rule."""
    cfg, params, want, _ = _baseline(False, "ragged")
    eng = _spec_engine(cfg, params, NGramProposer(max_ngram=3, history=8),
                       k=4)
    done, _ = _serve(eng, _prompts(cfg))
    assert done == want
    assert eng.pages_in_use == 0


# ------------------------------------------------ acceptance-rule property --

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_acceptance_commits_exactly_longest_matching_prefix(seed):
    """Single lane, per-call random corruption position: every drafting
    step must commit exactly ``longest matching prefix + 1`` tokens —
    checked against the proposer's own log of (drafts, true continuation)
    using the step's drafted/accepted accounting."""
    rng = np.random.default_rng(seed)
    cfg, params, want, _ = _baseline(False, "ragged")
    prompts = _prompts(cfg)[:1]
    prop = ScriptedProposer(
        _truth(cfg, want), cfg.vocab_size,
        corrupt=lambda i, d: int(v) if (v := rng.integers(0, d + 1)) < d
        else None)
    eng = _spec_engine(cfg, params, prop, k=4, lanes=1)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=MAX_NEW))
    li = 0
    while eng.scheduler.has_work():
        out = eng.step()
        if not out.drafted_tokens:
            continue
        drafts, cont = prop.log[li]
        li += 1
        # the scheduler may have trimmed the proposal (budget/bucket):
        # the plan kept the oldest prefix of it
        drafts = drafts[:out.drafted_tokens]
        exp = 0
        while exp < len(drafts) and drafts[exp] == cont[exp]:
            exp += 1
        assert out.accepted_tokens == exp, (drafts, cont, out)
    assert li == len(prop.log), "drafting steps and proposer log diverged"
    assert by_uid(eng.finished)[0] == want[0]


# ----------------------------------------------------- pool-state rollback --

def test_rejected_drafts_leave_pool_identical_to_never_drafting():
    """Twin engines in lockstep — one drafting (every draft rejected), one
    plain.  After EVERY step: identical refcounts, identical free heap
    (as a multiset: pop-min allocation makes it identical in order too),
    identical page tables and cursors.  Rollback is provably 'as if the
    drafts never happened', not just 'eventually cleaned up'.

    Single lane on purpose: with lanes sharing a step, drafts legitimately
    change *other* lanes' pacing — bucket trim cuts drafts before prefill
    tails, so a co-scheduled prefill can keep rows the plain engine's trim
    would shave (a throughput win, covered by the packing tests) — and two
    lanes allocating in one step can pop heap pages in a different order.
    Neither is rollback; one lane pins both, making the claim exact."""
    cfg, params, want, _ = _baseline(False, "ragged")
    prompts = _prompts(cfg)
    prop = ScriptedProposer(_truth(cfg, want), cfg.vocab_size,
                            corrupt=lambda i, d: 0)
    plain = EngineCore(cfg, params, lanes=1, page_size=PS,
                       num_pages=PAGES, chunk_size=CHUNK, mode="ragged")
    spec = _spec_engine(cfg, params, prop, k=4, lanes=1)
    for i, p in enumerate(prompts):
        plain.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
        spec.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    drafted = 0
    while plain.scheduler.has_work() or spec.scheduler.has_work():
        plain.step()
        out = spec.step()
        drafted += out.drafted_tokens
        assert out.accepted_tokens == 0
        assert spec.kv.ref == plain.kv.ref
        assert sorted(spec.kv.free) == sorted(plain.kv.free)
        assert ([(r.req.uid, r.rows, r.pages)
                 for r in spec.scheduler.running]
                == [(r.req.uid, r.rows, r.pages)
                    for r in plain.scheduler.running])
    assert drafted > 0, "twin test never drafted"
    assert by_uid(spec.finished) == by_uid(plain.finished) == want
    assert spec.pages_in_use == plain.pages_in_use == 0


def test_abort_after_drafting_leaves_pool_identical_to_never_drafting():
    """Abort arm of the twin test: cancel the resident request right after
    a drafting step — the instant a lane's page table may still cover the
    speculative worst case (cursor + 1 + draft rows).  ``abort`` must
    route the surplus through ``uncommit`` before publish/release, so the
    refcounts and free heap stay identical to the never-drafted twin
    *through* the abort, the survivors drain token-identically, and the
    pool empties.  Same single-lane lockstep discipline as above."""
    cfg, params, want, _ = _baseline(False, "ragged")
    prompts = _prompts(cfg)
    prop = ScriptedProposer(_truth(cfg, want), cfg.vocab_size,
                            corrupt=lambda i, d: 0)
    plain = EngineCore(cfg, params, lanes=1, page_size=PS,
                       num_pages=PAGES, chunk_size=CHUNK, mode="ragged")
    spec = _spec_engine(cfg, params, prop, k=4, lanes=1)
    for i, p in enumerate(prompts):
        plain.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
        spec.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    aborted = None
    while plain.scheduler.has_work() or spec.scheduler.has_work():
        plain.step()
        out = spec.step()
        if aborted is None and out.drafted_tokens:
            aborted = spec.scheduler.running[0].req.uid
            assert spec.abort(aborted) and plain.abort(aborted)
        assert spec.kv.ref == plain.kv.ref
        assert sorted(spec.kv.free) == sorted(plain.kv.free)
        assert ([(r.req.uid, r.rows, r.pages)
                 for r in spec.scheduler.running]
                == [(r.req.uid, r.rows, r.pages)
                    for r in plain.scheduler.running])
    assert aborted is not None, "abort arm never drafted"
    survivors = {u: t for u, t in want.items() if u != aborted}
    assert by_uid(spec.finished) == by_uid(plain.finished) == survivors
    assert spec.pages_in_use == plain.pages_in_use == 0


# ------------------------------------------- scheduler chunk-aware packing --

def _rng_proposer(rng, vocab):
    """Deterministic fake proposer for jax-free scheduler tests: draft
    length and tokens keyed off the rng stream."""
    def prop(stream, k):
        d = int(rng.integers(0, k + 1))
        return [int(t) for t in rng.integers(0, vocab, d)]
    return prop


def _make_spec_scheduler(num_pages=64, lanes=3, chunk=8, step_tokens=None,
                         spec_k=4, proposer=None, page_size=8,
                         token_buckets=None):
    from repro.models import build_model
    from repro.serving import PagedKVCache
    from repro.configs import get_config
    cfg = get_config("deepseek-7b-smoke")
    kv = PagedKVCache(build_model(cfg), num_pages, page_size)
    return Scheduler(kv, lanes=lanes, chunk_size=chunk,
                     step_tokens=step_tokens, spec_k=spec_k,
                     proposer=proposer, token_buckets=token_buckets), cfg


def _sim_spec_engine(sched, batch, rng):
    """Advance scheduler state the way EngineCore._finish would for a
    drafting step — commit a random prefix of each lane's drafts plus the
    bonus token, uncommit the surplus pages — without any jax compute."""
    for p in batch.plans:
        run, req = p.run, p.run.req
        if not p.sample:
            run.rows += p.q_len
            continue
        d = len(p.drafts)
        acc = int(rng.integers(0, d + 1)) if d else 0
        n, done = 0, False
        for _ in range(acc + 1):
            req.tokens.append(0)
            n += 1
            if len(req.tokens) >= req.max_new:
                done = True
                break
        run.rows += (p.q_len - d) + n - 1
        if d:
            run.pages = sched.kv.uncommit(run.pages, run.rows)
        if done:
            sched.finish(run)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_spec_packing_properties(seed):
    """Packing invariants survive 1+k decode chunks: budget respected by
    the whole stream, width is the tightest bucket, cu_seqlens ↔ pos ↔
    stream-token consistency (drafts ride the stream at cursor-relative
    positions), drafts only ever extend greedy decode lanes whose
    mandatory token is intact, and pages cover the drafted worst case.
    Random accept fractions drain the pool back to empty."""
    rng = np.random.default_rng(seed)
    sched, cfg = _make_spec_scheduler(
        proposer=_rng_proposer(np.random.default_rng(seed + 1),
                               cfg_vocab := 512))
    for uid in range(int(rng.integers(2, 7))):
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg_vocab,
                                int(rng.integers(1, 30))).astype(np.int32),
            max_new=int(rng.integers(1, 12))))
    steps = drafted = 0
    while sched.has_work():
        steps += 1
        assert steps < 1000, "scheduler did not drain"
        rows_before = {r.ticket: r.rows for r in sched.running}
        batch, _ = sched.schedule_ragged()
        plans, cu = batch.plans, batch.cu_seqlens
        assert batch.live == sum(p.q_len for p in plans) == int(cu[-1])
        assert batch.live <= sched.step_tokens
        assert batch.width in sched.token_buckets
        tighter = [w for w in sched.token_buckets
                   if max(batch.live, 1) <= w < batch.width]
        assert not tighter
        for i, p in enumerate(plans):
            lo, hi = int(cu[i]), int(cu[i + 1])
            d = len(p.drafts)
            drafted += d
            assert hi - lo == p.q_len
            start = rows_before.get(p.run.ticket, 0)
            np.testing.assert_array_equal(
                batch.pos[lo:hi], start + np.arange(p.q_len))
            np.testing.assert_array_equal(batch.tokens[lo:hi],
                                          p.stream_tokens())
            if d:
                # drafts extend a decode lane: mandatory token intact,
                # drafts past the known stream, pages cover the worst case
                assert p.q_len - d == 1 and p.run.remaining() == 1
                assert p.sample
                np.testing.assert_array_equal(
                    batch.tokens[lo + 1:hi], np.asarray(p.drafts, np.int32))
            assert len(p.run.pages) >= sched.kv.pages_needed(
                start + p.q_len)
        _sim_spec_engine(sched, batch, rng)
        for r in sched.running:     # post-commit: no speculative surplus
            assert len(r.pages) == sched.kv.pages_needed(r.rows), (
                "pages beyond the committed cursor survived the step")
    assert sched.kv.free_pages == sched.kv.num_pages
    assert all(r == 0 for r in sched.kv.ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_spec_packing_under_preemption(seed):
    """A pool far too small for the offered load, with drafting on: the
    packing invariants hold while evicting, evicted requests rewind clean
    (no pages, cursor 0), draft grants never leak pages, and the stream
    drains with the pool fully restored."""
    rng = np.random.default_rng(seed)
    sched, _ = _make_spec_scheduler(
        num_pages=8, lanes=3, chunk=4, page_size=8,
        proposer=_rng_proposer(np.random.default_rng(seed + 1), 512))
    for uid in range(4):
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, 512,
                                int(rng.integers(4, 16))).astype(np.int32),
            max_new=int(rng.integers(4, 12))))
    steps = 0
    while sched.has_work():
        steps += 1
        assert steps < 3000, "did not drain under preemption + drafting"
        batch, _ = sched.schedule_ragged()
        assert batch.live <= sched.step_tokens
        assert batch.width in sched.token_buckets
        for r in sched.waiting:
            assert r.rows == 0 and r.pages == []
        _sim_spec_engine(sched, batch, rng)
    assert sched.kv.free_pages == sched.kv.num_pages
    assert all(r == 0 for r in sched.kv.ref)


def test_budget_starved_step_degrades_k_not_residents():
    """The chunk-aware fairness fix: mandatory decode tokens (1/lane) are
    funded first, drafts only from leftovers.  step_tokens = lanes leaves
    zero leftover → no drafts, every lane still planned; step_tokens =
    lanes + 2 funds exactly 2 draft tokens, oldest lane first; nobody is
    evicted in either case."""
    greedy4 = lambda s, k: [0] * k
    sched, _ = _make_spec_scheduler(lanes=3, step_tokens=3, spec_k=4,
                                    proposer=greedy4,
                                    token_buckets=(1, 2, 3, 4, 5, 8))
    for uid in range(3):
        sched.submit(Request(uid=uid, prompt=np.array([1 + uid], np.int32),
                             max_new=20))
    batch, preempted = sched.schedule_ragged()
    assert not preempted and sched.preempted_count == 0
    assert len(batch.plans) == 3
    assert all(p.q_len == 1 and p.drafts == () for p in batch.plans)

    sched2, _ = _make_spec_scheduler(lanes=3, step_tokens=5, spec_k=4,
                                     proposer=greedy4,
                                     token_buckets=(1, 2, 3, 4, 5, 8))
    for uid in range(3):
        sched2.submit(Request(uid=uid, prompt=np.array([1 + uid], np.int32),
                              max_new=20))
    batch2, preempted2 = sched2.schedule_ragged()
    assert not preempted2 and sched2.preempted_count == 0
    by_ticket = sorted(batch2.plans, key=lambda p: p.run.ticket)
    assert [len(p.drafts) for p in by_ticket] == [2, 0, 0]
    assert [p.q_len for p in by_ticket] == [3, 1, 1]
    assert batch2.live == 5 <= sched2.step_tokens


def test_page_pressure_degrades_drafts_not_residents():
    """Draft rows are never worth an eviction: with one free page left,
    the oldest decode lane keeps its full draft (it fits free) and the
    younger lane sheds ALL drafts rather than preempting anyone — both
    lanes still run their mandatory token."""
    sched, _ = _make_spec_scheduler(num_pages=3, lanes=2, chunk=8,
                                    page_size=4, spec_k=4, proposer=None)
    for uid in range(2):
        sched.submit(Request(
            uid=uid, prompt=np.arange(1, 4, dtype=np.int32), max_new=8))
    rng = np.random.default_rng(0)
    # stream the 3-token prompts through (samples once: both lanes decode)
    batch, _ = sched.schedule_ragged()
    _sim_spec_engine(sched, batch, rng)
    assert all(r.remaining() == 1 for r in sched.running)
    sched.proposer = lambda s, k: [0] * k          # now start drafting
    batch, preempted = sched.schedule_ragged()
    assert not preempted and sched.preempted_count == 0
    by_ticket = sorted(batch.plans, key=lambda p: p.run.ticket)
    assert len(by_ticket) == 2
    # lane 0: rows 3 → 8 needs one extra page; exactly one is free
    assert len(by_ticket[0].drafts) == 4 and by_ticket[0].q_len == 5
    # lane 1: nothing free without eviction → mandatory token only
    assert len(by_ticket[1].drafts) == 0 and by_ticket[1].q_len == 1


# -------------------------------------------------- compile-level gates --

def _prim_counts(jaxpr, acc=None):
    """Histogram of primitive names, nested subjaxprs included."""
    acc = {} if acc is None else acc
    for eqn in jaxpr.eqns:
        acc[eqn.primitive.name] = acc.get(eqn.primitive.name, 0) + 1
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    _prim_counts(v.jaxpr, acc)
                elif isinstance(v, jax.core.Jaxpr):
                    _prim_counts(v, acc)
    return acc


def test_verify_graph_is_one_varlen_attend():
    """The verify step is the SAME graph as the plain ragged step — the
    drafted rows ride the packed stream through one varlen attend.  The
    spec trace (2-D last_idx) must match the plain trace (1-D last_idx)
    primitive-for-primitive on everything that could hide a per-draft
    loop or a re-attend (dot_general / scan / while counts), contain no
    (lanes, C)-padded intermediate, and no rank ≥ 4 (lanes, 1+k)-leading
    gathered-KV tensor."""
    from tests.test_engine_core import _sampling_args
    from tests.test_paged_serving import _jaxpr_shapes

    cfg, params = build()
    lanes, k, ps = 3, 4, 8
    eng = _spec_engine(cfg, params, proposer=lambda s, n: [], k=k,
                       lanes=lanes)
    t, pw = 16, 4           # 3 decode lanes with 1+4 rows each, bucketed
    args = (eng.params, eng.kv.pool,
            jnp.full((t, pw), eng.kv.scratch, jnp.int32),
            jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32))
    # lane boundaries in the serving (lanes + 2,) convention: 3 lanes with
    # 1 + k rows each, then the trailing pseudo-segment ending at T
    cu = jnp.asarray([0, 5, 10, 15, t, t], jnp.int32)
    spec_jaxpr = jax.make_jaxpr(eng._ragged)(
        *args, jnp.zeros((lanes, k + 1), jnp.int32), cu,
        *_sampling_args(lanes))
    plain_jaxpr = jax.make_jaxpr(eng._ragged)(
        *args, jnp.zeros((lanes,), jnp.int32), cu, *_sampling_args(lanes))

    spec_c, plain_c = (_prim_counts(j.jaxpr)
                       for j in (spec_jaxpr, plain_jaxpr))
    for prim in ("dot_general", "scan", "while"):
        assert spec_c.get(prim, 0) == plain_c.get(prim, 0), (
            f"{prim}: {spec_c.get(prim, 0)} vs {plain_c.get(prim, 0)} — "
            f"the verify step added compute beyond the logit gather")
    assert spec_c.get("dot_general", 0) > 0      # sanity: detector sees ops

    shapes = list(_jaxpr_shapes(spec_jaxpr.jaxpr))
    bad = [s for s in shapes
           if len(s) >= 4 and s[0] == lanes and s[1] == k + 1]
    assert not bad, f"(lanes, 1+k)-gathered KV intermediate: {bad}"
    chunk = eng.chunk_size
    padded = [s for s in shapes
              if any(s[i] == lanes and s[i + 1] == chunk
                     for i in range(len(s) - 1))]
    assert not padded, f"(lanes, C)-padded intermediate: {padded}"


def test_spec_k_is_static_O1_compiles():
    """k is a shape constant, draft count is data: a proposer whose draft
    length varies 0..k step to step — across a warm-up stream of many
    distinct prompt lengths — compiles the same O(bucket set) step
    functions as ever, and a second stream of new lengths (and new draft
    counts) traces nothing at all."""
    cfg, params = build()
    vary = lambda s, k: [int(s[-1])] * (len(s) % (k + 1))
    eng = _spec_engine(cfg, params, proposer=vary, k=4, lanes=1,
                       num_pages=64)

    def serve(lens, seed):
        for i, p in enumerate(prompts_for(cfg, seed, lens)):
            eng.submit(Request(uid=seed * 100 + i, prompt=p, max_new=4))
        while eng.scheduler.has_work():
            eng.step()
        eng.finished.clear()

    # two warm-up streams cover every reachable (width bucket × table
    # width) combo the draft-length cycle can produce — including drafted
    # widths past the 4-page table boundary (prompts > 32 rows)
    serve(tuple(range(2, 23)) + (24, 27, 29), seed=1)
    serve((23, 25, 26, 28, 30, 31, 33, 34, 36, 38, 40), seed=2)
    traced = eng.trace_count
    widths = len(eng.scheduler.token_buckets) + 2    # + padded-block widths
    assert traced <= 4 * widths, (traced, widths)
    assert eng.drafted_total > 0, "draft-count variety never exercised"
    serve((32, 35, 37, 39, 41), seed=3)              # 5 new distinct lengths
    assert eng.trace_count == traced, (
        f"varying draft counts retraced the step: {traced} → "
        f"{eng.trace_count}")


# ----------------------------------------------------------- constructor --

def test_speculative_requires_ragged_mode():
    cfg, params = build()
    with pytest.raises(ValueError, match="ragged"):
        EngineCore(cfg, params, mode="padded", speculative=True)
    with pytest.raises(ValueError, match="spec_k"):
        EngineCore(cfg, params, mode="ragged", speculative=True, spec_k=0)


def test_step_output_spec_accounting_defaults_zero():
    """Non-speculative engines report zero drafted/accepted — the fields
    exist on every StepOutput so bench/telemetry code never branches."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=16)
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 9, (5,))[0],
                       max_new=2))
    out = eng.step()
    assert isinstance(out, StepOutput)
    assert out.drafted_tokens == 0 and out.accepted_tokens == 0
