"""Paged-attention backend: equivalence vs the contiguous oracles over
shuffled page tables / ragged lengths / GQA ratios / int8 pools, registry
resolution, and the no-gathered-view graph guarantee."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.core.attention_api import (AttentionCall, attention,
                                      resolve_backend)
from repro.core.streaming_attention import quantize_kv_rows
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_reference)


def make_pool(rng, n, hkv, ps, d):
    return (jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32)))


def shuffled_tables(rng, b, p, n):
    """Each lane's pages drawn without replacement, in random pool order."""
    return jnp.asarray(np.stack([rng.permutation(n)[:p] for _ in range(b)]),
                       jnp.int32)


def gather_view(pool, tbl):
    """(N, Hkv, ps, D) + (B, P) → the contiguous (B, Hkv, P·ps, D) view the
    in-place path exists to avoid — used here only as the oracle input."""
    out = jnp.moveaxis(jnp.take(pool, tbl, axis=0), 1, 2)
    s = out.shape
    return out.reshape(s[0], s[1], s[2] * s[3], *s[4:])


def oracle(backend, q, kg, vg, lens, **kw):
    """Per-lane contiguous-backend attention at each lane's own length."""
    outs = []
    for i in range(q.shape[0]):
        li = int(lens[i])
        outs.append(attention(q[i:i + 1], kg[i:i + 1], vg[i:i + 1],
                              backend=backend, causal=True,
                              q_offset=li - 1, kv_len=li, exp_mode="lut",
                              **kw))
    return np.concatenate([np.asarray(o) for o in outs], axis=0)


# ------------------------------------------------------------- equivalence --

@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4),              # GQA group size
       st.integers(1, 3),              # batch lanes
       st.sampled_from([4, 8, 16]),    # page size
       st.integers(2, 5),              # table width (pages per lane)
       st.integers(0, 10_000))         # seed
def test_paged_matches_contiguous_backends(group, b, ps, p, seed):
    """Reference paged attention == naive/jnp on the gathered view, for
    shuffled tables, ragged per-lane lengths and every GQA packing."""
    rng = np.random.default_rng(seed)
    hkv, d = 2, 16
    hq = hkv * group
    n = p * b + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray(rng.integers(1, p * ps + 1, size=b), jnp.int32)

    got = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens,
                                               exp_mode="lut"))
    kg, vg = gather_view(kp, tbl), gather_view(vp, tbl)
    for backend in ("naive", "jnp"):
        want = oracle(backend, q, kg, vg, lens)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4,
                                   err_msg=backend)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([4, 8]), st.integers(0, 10_000))
def test_paged_kernel_interpret_matches_reference(group, ps, seed):
    """The Pallas kernel (interpret mode) == the jnp page-block reference."""
    rng = np.random.default_rng(seed)
    b, hkv, d, p = 2, 2, 16, 3
    n = p * b + 2
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hkv * group, 1, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray(rng.integers(1, p * ps + 1, size=b), jnp.int32)

    ref = paged_attention_reference(q, kp, vp, tbl, lens, exp_mode="lut")
    ker = paged_attention(q, kp, vp, tbl, lens, exp_mode="lut",
                          interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_paged_int8_pool_close_to_float(rng):
    """INT8 pools (per-row scales, dequantised per page block) track the
    float path within quantisation error, on both reference and kernel."""
    b, hq, hkv, d, ps, p = 2, 4, 2, 32, 8, 4
    n = p * b + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray([13, 29], jnp.int32)

    def quant(pool):
        qv, s = quantize_kv_rows(pool.reshape(1, n * hkv, ps, d))
        return qv.reshape(n, hkv, ps, d), s.reshape(n, hkv, ps)

    kq, ks = quant(kp)
    vq, vs = quant(vp)
    want = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens))
    for impl in (paged_attention_reference,
                 lambda *a, **kw: paged_attention(*a, **kw, interpret=True)):
        got = np.asarray(impl(q, kq, vq, tbl, lens,
                              k_scale=ks, v_scale=vs))
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        assert rel < 0.02, rel


def test_paged_window_and_softcap(rng):
    """Sliding-window + logit-softcap masking agree with the naive oracle."""
    b, hq, hkv, d, ps, p = 2, 4, 2, 16, 8, 4
    n = p * b
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray([9, 27], jnp.int32)
    kw = dict(window=7, cap=15.0)

    got = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens, **kw))
    want = oracle("naive", q, gather_view(kp, tbl), gather_view(vp, tbl),
                  lens, **kw)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_paged_via_attention_api(rng):
    """attention(page_table=...) resolves to the paged backend and matches
    calling the kernel module directly."""
    b, hq, hkv, d, ps, p = 2, 4, 2, 16, 8, 3
    n = 8
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray([5, 20], jnp.int32)

    via_api = attention(q, kp, vp, backend="auto", causal=True,
                        kv_len=lens, page_table=tbl)
    direct = paged_attention(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(via_api), np.asarray(direct),
                               atol=0, rtol=0)


# ------------------------------------------------------- chunked prefill --

def chunk_oracle(backend, q, kg, vg, lens, **kw):
    """Per-lane contiguous-backend attention for a query *chunk* whose rows
    end at each lane's live length (q_offset = len - Lq)."""
    lq = q.shape[2]
    outs = []
    for i in range(q.shape[0]):
        li = int(lens[i])
        outs.append(attention(q[i:i + 1], kg[i:i + 1], vg[i:i + 1],
                              backend=backend, causal=True,
                              q_offset=li - lq, kv_len=li, exp_mode="lut",
                              **kw))
    return np.concatenate([np.asarray(o) for o in outs], axis=0)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3),              # GQA group size
       st.integers(1, 6),              # chunk length Lq
       st.sampled_from([4, 8]),        # page size
       st.integers(0, 10_000))         # seed
def test_chunked_prefill_matches_contiguous_backends(group, lq, ps, seed):
    """Multi-row paged queries (the chunked-prefill path) == naive/jnp on
    the gathered view with the same causal intra-chunk mask, over shuffled
    tables, ragged per-lane lengths and GQA packings."""
    rng = np.random.default_rng(seed)
    b, hkv, d, p = 2, 2, 16, 4
    hq = hkv * group
    n = p * b + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, lq, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray(rng.integers(lq, p * ps + 1, size=b), jnp.int32)

    got = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens,
                                               exp_mode="lut"))
    kg, vg = gather_view(kp, tbl), gather_view(vp, tbl)
    for backend in ("naive", "jnp"):
        want = chunk_oracle(backend, q, kg, vg, lens)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4,
                                   err_msg=backend)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.integers(2, 5), st.integers(0, 10_000))
def test_chunked_kernel_interpret_matches_reference(group, lq, seed):
    """The Pallas kernel (interpret mode) == the jnp reference for
    multi-row chunks — the per-row causal bound lives in both."""
    rng = np.random.default_rng(seed)
    b, hkv, d, ps, p = 2, 2, 16, 8, 3
    n = p * b + 2
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hkv * group, lq, d))
                    .astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray(rng.integers(lq, p * ps + 1, size=b), jnp.int32)

    ref = paged_attention_reference(q, kp, vp, tbl, lens, exp_mode="lut")
    ker = paged_attention(q, kp, vp, tbl, lens, exp_mode="lut",
                          interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_chunked_window_and_int8(rng):
    """Sliding window masks per query row, and int8 pools track float —
    on the chunked path specifically."""
    b, hq, hkv, d, ps, p, lq = 2, 4, 2, 32, 8, 4, 5
    n = p * b + 1
    kp, vp = make_pool(rng, n, hkv, ps, d)
    q = jnp.asarray(rng.normal(size=(b, hq, lq, d)).astype(np.float32))
    tbl = shuffled_tables(rng, b, p, n)
    lens = jnp.asarray([13, 29], jnp.int32)

    got = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens,
                                               window=7, cap=15.0))
    want = chunk_oracle("naive", q, gather_view(kp, tbl),
                        gather_view(vp, tbl), lens, window=7, cap=15.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)

    def quant(pool):
        qv, s = quantize_kv_rows(pool.reshape(1, n * hkv, ps, d))
        return qv.reshape(n, hkv, ps, d), s.reshape(n, hkv, ps)

    kq, ks = quant(kp)
    vq, vs = quant(vp)
    base = np.asarray(paged_attention_reference(q, kp, vp, tbl, lens))
    for impl in (paged_attention_reference,
                 lambda *a, **kw: paged_attention(*a, **kw, interpret=True)):
        got = np.asarray(impl(q, kq, vq, tbl, lens, k_scale=ks, v_scale=vs))
        rel = np.linalg.norm(got - base) / np.linalg.norm(base)
        assert rel < 0.02, rel


# --------------------------------------------------------------- registry --

def _call(**kw):
    base = dict(lq=1, lkv=8, platform="cpu", static_lengths=False,
                has_kv_pos=False, inside_shard_map=False,
                has_page_table=True)
    base.update(kw)
    return AttentionCall(**base)


def test_resolution_paged_calls_only_reach_paged():
    assert resolve_backend("auto", _call()).name == "paged"
    # chunked prefill (multi-row queries with a page table) resolves too
    assert resolve_backend("auto", _call(lq=4)).name == "paged"
    # contiguous backends refuse pool+page-table calls even explicitly
    for name in ("naive", "naive_decode", "jnp", "pallas"):
        with pytest.raises(ValueError, match="does not support"):
            resolve_backend(name, _call())


def test_resolution_contiguous_calls_never_pick_paged():
    call = _call(has_page_table=False, static_lengths=True)
    assert resolve_backend("auto", call).name != "paged"
    with pytest.raises(ValueError, match="does not support"):
        resolve_backend("paged", call)
