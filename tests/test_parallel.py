"""Sharding rules, pipeline parallelism, sharded-vs-single equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.models import build_model
from repro.parallel import fit_spec, param_pspec, param_specs
from repro.parallel.compat import make_mesh
from tests._multidevice import run_with_devices


# ------------------------------------------------------------- fit_spec --

def test_fit_spec_basic():
    # single-device mesh: every axis has size 1 → everything fits
    mesh = make_mesh((1, 1), ("data", "model"))
    assert fit_spec(("fsdp", "tp"), (16, 32), mesh) == P("data", "model")
    assert fit_spec(("dp", None), (3, 7), mesh) == P("data", None)


def test_param_specs_always_divisible():
    """Property: for every assigned arch, every arg spec divides its dim
    (jit in_shardings hard requirement) — checked on a fake 16×16 mesh."""
    out = run_with_devices("""
        import jax
        from repro.configs import ASSIGNED, get_config
        from repro.models import build_model, input_specs
        from repro.parallel import param_specs, batch_specs, cache_specs
        from repro.launch.mesh import make_production_mesh
        from repro.parallel.compat import make_mesh

        # 16-device stand-in mesh with the production axis names
        mesh = make_mesh((4, 4), ("data", "model"))

        def check(tree, specs):
            leaves = jax.tree_util.tree_leaves_with_path(tree)
            spec_leaves = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, type(specs)) or True)
            flat_specs = jax.tree_util.tree_leaves(specs)
            for (kp, leaf), spec in zip(leaves, flat_specs):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None: continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    size = 1
                    for a in axes: size *= mesh.shape[a]
                    assert dim % size == 0, (kp, leaf.shape, spec)

        for name in ASSIGNED:
            cfg = get_config(name)
            model = build_model(cfg)
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            check(params, param_specs(params, mesh))
            si = input_specs(cfg, "decode", 1024, 16)
            check(si["state"], cache_specs(si["state"], mesh))
        print("OK")
    """, n_devices=16)
    assert "OK" in out


def test_param_pspec_rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert param_pspec("trunk/periods/0/attn/wq/w", (4, 64, 64), mesh) \
        == P(None, "data", "model")
    assert param_pspec("embed/tokens", (512, 64), mesh) == P("model", "data")
    assert param_pspec("trunk/periods/0/ln1/scale", (4, 64), mesh) \
        == P(None, None)
    assert param_pspec("trunk/periods/0/moe/up", (4, 8, 64, 128), mesh) \
        == P(None, None, "data", "model")


# ------------------------------------------------------------- pipeline --

def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import pipeline_apply
        from repro.parallel.compat import make_mesh
        mesh = make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        S, M, mb, d = 4, 6, 3, 8
        ws = jnp.asarray(rng.normal(size=(S, d, d)).astype(np.float32) * 0.3)
        bs = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))
        f = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
        out = pipeline_apply(f, {"w": ws, "b": bs}, x, mesh, axis="pod")
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ ws[s] + bs[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        print("OK")
    """, n_devices=4)
    assert "OK" in out


# ------------------------------------------- sharded == single device --

def test_sharded_train_step_matches_single():
    """The same loss on a 2×4 mesh and on CPU-1 — distribution must not
    change the math."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel import (param_specs, batch_specs, shard_tree,
                                    activation_sharding)
        from repro.parallel.compat import make_mesh

        cfg = get_config("deepseek-7b-smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        loss_single, _ = model.loss(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        pspecs = param_specs(params, mesh)
        sparams = shard_tree(params, pspecs, mesh)
        bspecs = batch_specs(batch, mesh)
        sbatch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                  for k, v in batch.items()}
        with activation_sharding(mesh):
            loss_sharded, _ = jax.jit(model.loss)(sparams, sbatch)
        d = abs(float(loss_single) - float(loss_sharded))
        assert d < 5e-3, d
        print("OK", d)
    """, n_devices=8)
    assert "OK" in out
