"""Roofline-guided kernel autotuner: candidate sweep, traffic model,
persistence, and the config-threading contract — a tuned `KernelConfig`
must reach the varlen kernel from every entry point (explicit argument,
process-wide active config, EngineCore resolution at init) and be recorded
where benchmarks can see it (StepOutput debug stats)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.autotune import (DEFAULT_CONFIG, KernelConfig, KernelGeom,
                                    active_config, candidate_space,
                                    default_workloads, geom_for,
                                    predict_step_s, resolve_config,
                                    save_config, set_active_config,
                                    table_path, tune)
from repro.perfmodel.model import (platform_spec, varlen_attention_roofline,
                                   varlen_attention_traffic)


@pytest.fixture(autouse=True)
def _isolate_active_config():
    """Never leak a pinned process-wide config between tests."""
    set_active_config(None)
    yield
    set_active_config(None)


# ----------------------------------------------------------- candidates ----

def test_candidate_space_contents():
    cands = candidate_space(page_size=8)
    assert len(cands) == len(set(cands))            # frozen → hashable, dedup
    assert KernelConfig(block_q=1, block_pages=1, dequant="block") in cands
    assert any(c.block_q == 1 for c in cands)       # untiled baseline kept
    assert {c.dequant for c in cands} == {"block", "page"}
    assert all(c.source == "default" for c in cands)
    small = candidate_space(page_size=8, max_block_q=8, max_block_pages=2)
    assert max(c.block_q for c in small) <= 8
    assert max(c.block_pages for c in small) <= 2


def test_geom_for_reads_model_config():
    from repro.configs import get_config
    cfg = get_config("deepseek-7b-smoke")
    g = geom_for(cfg, page_size=8, quantized=True)
    assert (g.hq, g.page_size, g.kv_bytes) == (cfg.num_heads, 8, 1)
    assert g.scaled


# -------------------------------------------------------- traffic model ----

def test_traffic_kv_bytes_fall_with_block_q():
    """The tentpole claim in analytic form: each KV page is read once per
    q-block, so bytes_kv on a prefill chunk falls ~Bq× as Bq grows (until
    one block covers the chunk)."""
    segments = [(32, 64)] * 4
    kw = dict(block_pages=2, page_size=8, hq=8, hkv=2, head_dim=64)
    byq = {bq: varlen_attention_traffic(segments, block_q=bq, **kw)
           for bq in (1, 4, 8, 16, 32)}
    kv = [byq[bq]["bytes_kv"] for bq in (1, 4, 8, 16, 32)]
    assert all(a > b for a, b in zip(kv, kv[1:])), kv
    assert byq[1]["bytes_kv"] > 3 * byq[8]["bytes_kv"]
    pages = [byq[bq]["pages_read"] for bq in (1, 4, 8, 16, 32)]
    assert all(a >= b for a, b in zip(pages, pages[1:])), pages


def test_traffic_decode_indifferent_to_block_q():
    """All-decode (1 new token per lane): tiling buys nothing — the sweep
    must be able to conclude Bq=1 is fine there."""
    segments = [(1, 256)] * 8
    kw = dict(block_pages=4, page_size=16, hq=8, hkv=2, head_dim=64)
    t1 = varlen_attention_traffic(segments, block_q=1, **kw)
    t8 = varlen_attention_traffic(segments, block_q=8, **kw)
    assert t1["bytes_kv"] == t8["bytes_kv"]


def test_traffic_grid_steps_fall_with_block_pages():
    segments = [(16, 128)] * 4
    kw = dict(block_q=8, page_size=8, hq=4, hkv=2, head_dim=32)
    steps = [varlen_attention_traffic(segments, block_pages=bp,
                                      **kw)["grid_steps"]
             for bp in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(steps, steps[1:])), steps


def test_roofline_terms():
    """max(mem, compute) + dispatch, plus the per-page dequant penalty only
    when dequant='page' actually splits the multiply."""
    spec = platform_spec("cpu")
    segments = [(16, 64)] * 2
    traffic = varlen_attention_traffic(
        segments, block_q=8, block_pages=4, page_size=8, hq=4, hkv=2,
        head_dim=32)
    base = varlen_attention_roofline(spec, traffic, block_pages=4)
    assert base > 0
    floor = max(traffic["bytes_total"] / (spec.mem_bw_gbs * 1e9),
                traffic["flops"] / spec.flops)
    assert base >= floor
    paged = varlen_attention_roofline(spec, traffic, block_pages=4,
                                      dequant="page")
    assert paged >= base
    single = varlen_attention_roofline(spec, traffic, block_pages=1,
                                       dequant="page")
    assert single == varlen_attention_roofline(spec, traffic, block_pages=1)


def test_predict_finite_over_whole_space():
    geom = KernelGeom(hq=4, hkv=2, head_dim=32, page_size=8)
    wl = default_workloads(lanes=4, chunk=16, decode_ctx=64)
    spec = platform_spec("cpu")
    for c in candidate_space(page_size=8):
        s = predict_step_s(c, geom, wl, spec)
        assert np.isfinite(s) and s > 0, c


# ---------------------------------------------------------------- tune -----

def test_tune_picks_tiled_for_prefill_and_reports_all():
    geom = KernelGeom(hq=4, hkv=2, head_dim=32, page_size=8)
    wl = {"prefill": [(32, 32)] * 4}
    winner, report = tune(geom, platform="cpu", workloads=wl)
    assert winner.source == "tuned"
    # the whole space plus the incumbent default
    assert len(report) == len(candidate_space(page_size=8)) + 1
    # tuned ≤ default under the tuner's own metric, by construction
    pred_default = next(r["predicted_s"] for r in report
                        if r["config"]["source"] == "default"
                        and r["config"]["block_pages"] is None)
    assert min(r["predicted_s"] for r in report) <= pred_default
    assert winner.block_q > 1        # prefill chunks reward tiling
    best_pred = min(r["predicted_s"] for r in report)
    assert any(r["config"]["block_q"] == winner.block_q
               and r["predicted_s"] == best_pred for r in report)


def test_tune_measure_rescores_finalists():
    geom = KernelGeom(hq=2, hkv=1, head_dim=16, page_size=4)
    wl = {"mixed": [(4, 8), (1, 8)]}
    winner, report = tune(geom, platform="cpu", workloads=wl, measure=True,
                          top_k_measure=2)
    timed = [r for r in report if "measured_s" in r]
    assert len(timed) == 2
    assert all(r["measured_s"] > 0 for r in timed)
    assert winner.source == "tuned"
    assert winner.describe()["block_q"] in {t["config"]["block_q"]
                                            for t in timed}


# --------------------------------------------------------- persistence -----

def test_save_resolve_roundtrip(tmp_path):
    path = tmp_path / "autotune.json"
    tuned = KernelConfig(block_q=16, block_pages=4, dequant="page",
                         source="tuned")
    save_config("smoke", "cpu", tuned, path=path)
    got = resolve_config("smoke", "cpu", path=path)
    assert (got.block_q, got.block_pages, got.dequant) == (16, 4, "page")
    assert got.source == "tuned"
    # platform fallback: an unknown model inherits default::cpu, not smoke's
    save_config("default", "cpu", KernelConfig(block_q=4, source="tuned"),
                path=path)
    assert resolve_config("other-model", "cpu", path=path).block_q == 4
    # no entry at all → the hardcoded default
    assert resolve_config("other-model", "tpu", path=path) == DEFAULT_CONFIG
    # the table is plain JSON, one entry per (model, platform)
    table = json.loads(path.read_text())
    assert set(table) == {"smoke::cpu", "default::cpu"}


def test_resolve_ignores_unknown_table_keys(tmp_path):
    """Forward compat: a table written by a newer repo (extra fields) must
    not crash resolution."""
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({"m::cpu": {
        "block_q": 8, "block_pages": 2, "dequant": "block",
        "source": "tuned", "tuned_at": "2026-08-09", "score": 1.5}}))
    got = resolve_config("m", "cpu", path=path)
    assert (got.block_q, got.block_pages) == (8, 2)


def test_env_var_points_at_table(tmp_path, monkeypatch):
    path = tmp_path / "env_table.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(path))
    assert table_path() == path
    save_config("m", "cpu", KernelConfig(block_q=32, source="tuned"))
    assert resolve_config("m", "cpu").block_q == 32
    monkeypatch.delenv("REPRO_AUTOTUNE_PATH")
    assert table_path().name == "autotune.json"
    assert table_path().parent.name == "configs"    # the committed table


def test_committed_repo_table_resolves():
    """The persisted per-(model, platform) table shipped in the repo parses
    and resolves for the smoke model on cpu."""
    p = table_path()
    assert p.exists(), "src/repro/configs/autotune.json missing"
    table = json.loads(p.read_text())
    assert table, "committed autotune table is empty"
    for key, entry in table.items():
        assert "::" in key
        assert entry["block_q"] >= 1
    got = resolve_config("deepseek-7b-smoke", "cpu")
    assert got.source in ("tuned", "default")


# ----------------------------------------------------- config threading ----

def _tiny_stream(rng, *, hq=4, hkv=2, d=16, ps=8, p=3, n=12):
    from repro.kernels.paged_attention import varlen_positions
    nq = np.array([1, 6, 3])
    lens = np.array([5, 6, 9])
    cu = np.concatenate([[0], np.cumsum(nq)]).astype(np.int32)
    t = int(cu[-1])
    lane_tbl = np.stack([rng.permutation(n)[:p] for _ in range(len(nq))])
    q = jnp.asarray(rng.normal(size=(t, hq, d)).astype(np.float32))
    tbl = jnp.asarray(lane_tbl[np.repeat(np.arange(len(nq)), nq)], jnp.int32)
    pos = jnp.asarray(varlen_positions(cu, lens))
    kp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n, hkv, ps, d)).astype(np.float32))
    return q, kp, vp, tbl, pos, cu


def test_attention_api_threads_kernel_config(rng):
    """attention(kernel_config=…) reaches the kernel: the traced graph is
    the tiled one (fewer pool gathers), and the numbers match both the
    direct tiled call and the untiled reference."""
    from repro.core.attention_api import attention
    from repro.kernels.paged_attention import (
        paged_attention_varlen, paged_attention_varlen_reference)
    from tests.test_ragged_attention import _pool_gather_rows

    q, kp, vp, tbl, pos, cu = _tiny_stream(rng)
    packed = jnp.moveaxis(q, 0, 1)[None]
    cfg_tiled = KernelConfig(block_q=4)
    cfg_flat = KernelConfig(block_q=1)

    def call(kc):
        return attention(packed, kp, vp, backend="auto", causal=True,
                         page_table=tbl, q_pos=pos, cu_seqlens=cu,
                         kernel_config=kc)

    want = np.asarray(paged_attention_varlen_reference(q, kp, vp, tbl, pos))
    got = np.asarray(jnp.moveaxis(call(cfg_tiled)[0], 0, 1))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)
    direct = paged_attention_varlen(q, kp, vp, tbl, pos, cu_seqlens=cu,
                                    block_q=4)
    np.testing.assert_allclose(got, np.asarray(direct), atol=0, rtol=0)

    pool_shape = tuple(kp.shape)
    rows = {kc.block_q: _pool_gather_rows(
        jax.make_jaxpr(lambda a: call(kc))(packed).jaxpr, pool_shape)
        for kc in (cfg_tiled, cfg_flat)}
    assert 0 < rows[4] < rows[1], rows


def test_active_config_hook(rng, tmp_path, monkeypatch):
    """No explicit config → `attention()` uses the process-wide active
    config; unset → on-disk resolution (pointed at an empty table here, so
    the hardcoded default)."""
    from repro.core.attention_api import attention
    from tests.test_ragged_attention import _pool_gather_rows

    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(tmp_path / "none.json"))
    assert active_config() == DEFAULT_CONFIG
    pinned = KernelConfig(block_q=2, source="tuned")
    set_active_config(pinned)
    assert active_config() == pinned

    q, kp, vp, tbl, pos, cu = _tiny_stream(rng)
    packed = jnp.moveaxis(q, 0, 1)[None]
    pool_shape = tuple(kp.shape)

    def trace_rows():
        # a FRESH closure per trace: jax caches traces on function identity,
        # which is exactly why EngineCore pins its config at init instead of
        # reading the hook inside a jitted step
        fn = lambda a: attention(a, kp, vp, backend="auto", causal=True,
                                 page_table=tbl, q_pos=pos, cu_seqlens=cu)
        return _pool_gather_rows(jax.make_jaxpr(fn)(packed).jaxpr,
                                 pool_shape)

    rows_pinned = trace_rows()
    set_active_config(KernelConfig(block_q=1))
    rows_flat = trace_rows()
    assert 0 < rows_pinned < rows_flat, (rows_pinned, rows_flat)


def test_engine_resolves_and_reports_config(tmp_path, monkeypatch):
    """EngineCore pins its config at init (explicit beats on-disk) and
    every ragged StepOutput carries it in debug stats."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import EngineCore, Request

    cfg = get_config("deepseek-7b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    table = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(table))
    save_config(cfg.name, jax.default_backend(),
                KernelConfig(block_q=16, block_pages=2, source="tuned"))
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                     chunk_size=16, mode="ragged")
    assert (eng.kernel_config.block_q, eng.kernel_config.source) == (16,
                                                                     "tuned")

    override = KernelConfig(block_q=4, source="tuned")
    eng2 = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                      chunk_size=16, mode="ragged", kernel_config=override)
    assert eng2.kernel_config == override

    rng = np.random.default_rng(0)
    eng2.submit(Request(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, 5).astype(np.int32), max_new=2))
    out = eng2.step()
    assert out.kernel_config == override.describe()
    assert out.kernel_config["source"] == "tuned"


def test_kernel_config_is_static_and_hashable():
    """The config closes over a jitted step as a static value — it must be
    frozen, hashable and equality-stable."""
    a = KernelConfig(block_q=8, block_pages=2)
    b = KernelConfig(block_q=8, block_pages=2)
    assert a == b and hash(a) == hash(b)
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.block_q = 4
