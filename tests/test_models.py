"""Cross-family model semantics: decode == full forward, MoE invariants,
ring caches, encoder-decoder consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, lm as LM
from repro.models.moe import _topk_dispatch, moe_capacity

DECODE_ARCHS = ["deepseek-7b", "starcoder2-3b", "gemma2-9b", "gemma3-12b",
                "falcon-mamba-7b", "zamba2-1.2b", "granite-moe-3b-a800m",
                "grok-1-314b", "internvl2-1b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_full_forward(name, rng):
    """Prefill+decode must reproduce the full-forward logits — the strongest
    end-to-end invariant (caches, positions, masks, ring buffers, SSM state
    all have to line up)."""
    cfg = get_config(name + "-smoke")
    if cfg.family == "moe":
        # capacity-dropping depends on token count; avoid drops so the
        # prefill+decode and full-forward routings agree exactly
        cfg = cfg.replace(moe_capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L, EXTRA = 2, 11, 5
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L + EXTRA)),
                       jnp.int32)
    batch = {"tokens": toks[:, :L]}
    if cfg.family == "vlm":
        prefix = jnp.asarray(rng.normal(
            size=(B, cfg.frontend_len, cfg.d_model)).astype(np.float32) * .05,
            jnp.bfloat16)
        batch["prefix_embed"] = prefix
    caches = m.init_cache(B, L + EXTRA + (cfg.frontend_len
                                          if cfg.family == "vlm" else 0))
    lg, state = m.prefill(params, batch, caches)
    outs = []
    lp = cfg.frontend_len if cfg.family == "vlm" else 0
    for t in range(EXTRA):
        lg, state = m.decode_step(params, toks[:, L + t], state,
                                  jnp.int32(lp + L + t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    full, _, _ = LM.lm_apply(cfg, params, toks,
                             prefix_embed=batch.get("prefix_embed"))
    want = full[:, lp + L: lp + L + EXTRA]
    err = float(jnp.max(jnp.abs(dec - want)))
    assert err < 5e-2, (name, err)


def test_encdec_decode_matches_forward(rng):
    cfg = get_config("seamless-m4t-large-v2-smoke")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    from repro.models import encdec as ED
    B, L, EXTRA = 2, 9, 4
    frames = jnp.asarray(rng.normal(
        size=(B, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.05,
        jnp.bfloat16)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L + EXTRA)),
                       jnp.int32)
    caches = m.init_cache(B, L + EXTRA)
    lg, state = m.prefill(params, {"frames": frames, "tokens": toks[:, :L]},
                          caches)
    outs = []
    for t in range(EXTRA):
        lg, state = m.decode_step(params, toks[:, L + t], state,
                                  jnp.int32(L + t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    enc = ED.encode(cfg, params, frames)
    ckv = ED.cross_kvs_init(cfg, params, enc)
    full, _ = ED.decode_trunk(cfg, params, toks, ckv)
    err = float(jnp.max(jnp.abs(dec - full[:, L:L + EXTRA])))
    assert err < 5e-2, err


# ------------------------------------------------------------------- MoE --

def test_moe_dispatch_invariants(rng):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    t, e, cap = 32, cfg.num_experts, 8
    probs = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(t, e)).astype(np.float32)), -1)
    dispatch, combine = _topk_dispatch(cfg, probs, cap)
    d = np.asarray(dispatch)
    # ≤ k slots per token; ≤ capacity tokens per expert slot
    assert (d.sum(axis=(1, 2)) <= cfg.experts_per_token + 1e-6).all()
    assert (d.sum(axis=0) <= 1 + 1e-6).all()      # one token per (e, c) slot
    # combine weights are dispatch-masked probabilities
    c = np.asarray(combine)
    assert ((c > 0) <= (d > 0)).all()


def test_moe_capacity_formula():
    cfg = get_config("granite-moe-3b-a800m")
    c = moe_capacity(cfg, 512)
    expect = cfg.moe_capacity_factor * cfg.experts_per_token * 512 / cfg.num_experts
    assert c >= expect and c % 8 == 0


def test_moe_forward_capacity_sweep(rng):
    """Higher capacity factor must not break shapes / make NaNs."""
    base = get_config("granite-moe-3b-a800m-smoke")
    x = jnp.asarray(rng.normal(size=(2, 16, base.d_model)).astype(np.float32))
    from repro.models.moe import moe_apply, moe_init
    for cf in (0.5, 1.0, 2.0):
        cfg = base.replace(moe_capacity_factor=cf)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        y, aux = moe_apply(cfg, p, x.astype(jnp.bfloat16))
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
        assert float(aux) > 0.0             # load-balance penalty active


# -------------------------------------------------------------- ring cache --

def test_ring_cache_memory_is_window_sized():
    cfg = get_config("gemma3-12b-smoke")   # 5:1 local:global, window=8
    m = build_model(cfg)
    caches = m.init_cache(2, 4096)
    leaves = jax.tree_util.tree_flatten_with_path(caches)[0]
    ring, full = 0, 0
    for kp, leaf in leaves:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if path.endswith("/k"):
            if leaf.shape[-2] == cfg.window:
                ring += 1
            elif leaf.shape[-2] == 4096:
                full += 1
    assert ring > 0 and full > 0, (ring, full)
    assert ring > full      # 5 local : 1 global


def test_scan_period_structure():
    """gemma3's 5:1 local-global pattern must fold into scan periods."""
    cfg = get_config("gemma3-12b")
    kinds, nper, tail = LM.period_layout(cfg)
    assert kinds == ("local",) * 5 + ("global",)
    assert nper * 6 + tail == 48
