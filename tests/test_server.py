"""Async front door: streaming, backpressure, cancellation, drain.

The server contracts (PR 8):

- stream identity — tokens streamed per-client by :class:`AsyncLMServer`
  are exactly the tokens the batch driver commits for the same requests;
- cancellation — a client breaking out of its stream aborts the request:
  pages are freed before the next step, full pages publish to the prefix
  cache, and the freed lane is reused (never wedged);
- backpressure — ``admission="reject"`` sheds load at the door with
  ``ServerOverloaded``; ``admission="wait"`` suspends clients and
  eventually serves everyone;
- validation — a bad request raises in the submitting client's own
  context and perturbs nobody else;
- shutdown — draining shutdown finishes resident work, ``drain=False``
  aborts it; new arrivals after close get ``ServerClosed``.

No pytest-asyncio here: each test owns its loop via ``asyncio.run``.
"""
import asyncio

import pytest

from repro.serving import (AsyncLMServer, EngineCore, InvalidRequest,
                           Request, RequestState, SamplingParams,
                           ServerClosed, ServerOverloaded, ServingEngine)
from tests.test_engine_core import build, by_uid, prompts_for


def engine(cfg, params, **kw):
    kw.setdefault("lanes", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("chunk_size", 8)
    return EngineCore(cfg, params, **kw)


def reqs_for(cfg, n, *, seed=0, max_new=6, **sp):
    prompts = prompts_for(cfg, seed, tuple(4 + 3 * i for i in range(n)))
    sampling = SamplingParams(**sp) if sp else None
    return [Request(uid=i, prompt=p, max_new=max_new, sampling=sampling)
            for i, p in enumerate(prompts)]


async def consume(server, req, *, cancel_after=None):
    toks = []
    async for tok in server.generate(req):
        toks.append(tok)
        if cancel_after is not None and len(toks) >= cancel_after:
            break
    return toks


# ------------------------------------------------------- stream identity --

def test_streams_match_batch_driver():
    """Concurrent async clients see exactly the batch driver's tokens."""
    cfg, params = build()
    want = by_uid(r for r in _drain_batch(cfg, params))

    eng = engine(cfg, params)

    async def main():
        async with AsyncLMServer(eng) as server:
            outs = await asyncio.gather(
                *[consume(server, r) for r in reqs_for(cfg, 5)])
        return outs, server.summary()

    outs, summary = asyncio.run(main())
    assert {i: t for i, t in enumerate(outs)} == want
    assert summary["requests"] == 5 and summary["cancelled"] == 0
    assert summary["tokens"] == sum(len(t) for t in want.values())
    assert summary["ttft_ms_p50"] <= summary["ttft_ms_p99"]
    assert eng.pages_in_use == 0


def _drain_batch(cfg, params):
    eng = engine(cfg, params)
    for r in reqs_for(cfg, 5):
        eng.submit(r)
    while eng.scheduler.has_work():
        eng.step()
    return eng.finished


def test_sampled_stream_through_server_is_seed_reproducible():
    cfg, params = build()

    def serve_once():
        eng = engine(cfg, params)

        async def main():
            async with AsyncLMServer(eng) as server:
                return await asyncio.gather(*[
                    consume(server, r)
                    for r in reqs_for(cfg, 3, temperature=1.0, seed=7)])
        return asyncio.run(main())

    assert serve_once() == serve_once()


# ---------------------------------------------------------- cancellation --

def test_cancel_frees_pages_and_survivors_finish():
    cfg, params = build()
    want = by_uid(r for r in _drain_batch(cfg, params))
    eng = engine(cfg, params)
    rs = reqs_for(cfg, 5, max_new=8)

    async def main():
        async with AsyncLMServer(eng) as server:
            outs = await asyncio.gather(*[
                consume(server, r, cancel_after=2 if r.uid == 3 else None)
                for r in rs])
        return outs, server.summary()

    outs, summary = asyncio.run(main())
    assert summary["cancelled"] == 1
    assert rs[3].state == RequestState.ABORTED
    assert len(outs[3]) == 2
    # survivors are token-identical to the batch driver — the abort
    # perturbed nothing (and its freed lane kept serving them)
    for uid in (0, 1, 2, 4):
        assert outs[uid][:6] == want[uid]
    assert eng.pages_in_use == 0           # cancelled pages were returned


def test_cancel_before_admission_never_reaches_engine():
    cfg, params = build()
    eng = engine(cfg, params, lanes=1)

    async def main():
        async with AsyncLMServer(eng) as server:
            task = asyncio.ensure_future(
                consume(server, reqs_for(cfg, 1, max_new=4)[0]))
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        return server.summary()

    summary = asyncio.run(main())
    assert summary["requests"] == 0
    assert not eng.scheduler.has_work()


# ----------------------------------------------------------- backpressure --

def test_admission_reject_sheds_burst():
    cfg, params = build()
    eng = engine(cfg, params, lanes=1)

    async def main():
        served, shed = [], 0
        async with AsyncLMServer(eng, max_waiting=1,
                                 admission="reject") as server:
            async def client(r):
                nonlocal shed
                try:
                    served.append(await consume(server, r))
                except ServerOverloaded:
                    shed += 1
            await asyncio.gather(*[client(r) for r in reqs_for(cfg, 8)])
        return served, shed

    served, shed = asyncio.run(main())
    assert shed > 0                       # the burst was shed at the door
    assert len(served) + shed == 8
    assert all(len(t) == 6 for t in served)


def test_admission_wait_serves_everyone():
    cfg, params = build()
    eng = engine(cfg, params, lanes=1)

    async def main():
        async with AsyncLMServer(eng, max_waiting=1) as server:
            return await asyncio.gather(
                *[consume(server, r) for r in reqs_for(cfg, 6)])

    outs = asyncio.run(main())
    assert len(outs) == 6 and all(len(t) == 6 for t in outs)


# ------------------------------------------------------------- validation --

def test_invalid_request_raises_in_client_context():
    cfg, params = build()
    eng = engine(cfg, params)
    good = reqs_for(cfg, 1)[0]
    bad = Request(uid=9, prompt=good.prompt, max_new=4,
                  sampling=SamplingParams(stop=((cfg.vocab_size + 5,),)))

    async def main():
        async with AsyncLMServer(eng) as server:
            with pytest.raises(InvalidRequest, match="vocab"):
                await consume(server, bad)
            return await consume(server, good)

    assert len(asyncio.run(main())) == 6  # the good client was unperturbed


# --------------------------------------------------------------- shutdown --

def test_shutdown_drains_then_refuses_new_work():
    cfg, params = build()
    eng = engine(cfg, params)
    rs = reqs_for(cfg, 2)

    async def main():
        server = await AsyncLMServer(eng).start()
        tasks = [asyncio.ensure_future(consume(server, r)) for r in rs]
        await asyncio.sleep(0)             # let clients enqueue
        await server.shutdown(drain=True)
        outs = [await t for t in tasks]
        with pytest.raises(ServerClosed):
            await consume(server, reqs_for(cfg, 1, seed=3)[0])
        return outs

    outs = asyncio.run(main())
    assert all(len(t) == 6 for t in outs)  # resident work finished


def test_shutdown_no_drain_aborts_in_flight():
    cfg, params = build()
    eng = engine(cfg, params)
    rs = reqs_for(cfg, 3, max_new=64)

    async def main():
        server = await AsyncLMServer(eng).start()
        tasks = [asyncio.ensure_future(consume(server, r)) for r in rs]
        while server.steps < 2:            # some tokens in flight
            await asyncio.sleep(0.01)
        await server.shutdown(drain=False)
        return [await t for t in tasks], server

    outs, server = asyncio.run(main())
    assert all(len(t) < 64 for t in outs)
    assert server.cancelled == 3
    assert eng.pages_in_use == 0


# ------------------------------------------------------ engine-level abort --

def test_engine_abort_running_frees_pages_and_publishes_prefix():
    cfg, params = build()
    eng = engine(cfg, params, prefix_cache=True)
    rs = reqs_for(cfg, 2, max_new=16)
    for r in rs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    before = eng.pages_in_use
    assert eng.abort(rs[1].uid)
    assert eng.pages_in_use < before       # pages freed within the call
    assert rs[1].state == RequestState.ABORTED
    # full pages of the aborted request's known prefix were published
    assert eng.prefix_cache.stats()["inserted_pages"] >= 1
    # the freed lane is reusable: new work admits and completes
    nxt = Request(uid=77, prompt=rs[0].prompt, max_new=4)
    eng.submit(nxt)
    while eng.scheduler.has_work():
        eng.step()
    assert len(nxt.tokens) == 4 and nxt.done
    assert not eng.abort(rs[1].uid)        # double-abort is a no-op


def test_engine_abort_waiting_request():
    cfg, params = build()
    eng = engine(cfg, params, lanes=1)
    rs = reqs_for(cfg, 3, max_new=4)
    for r in rs:
        eng.submit(r)
    eng.step()                             # uid 0 admitted; 1, 2 waiting
    assert eng.abort(rs[2].uid)
    assert rs[2].state == RequestState.ABORTED
    while eng.scheduler.has_work():
        eng.step()
    assert rs[0].done and rs[1].done and not rs[2].tokens
    assert eng.pages_in_use == 0


def test_server_requires_abortable_engine():
    cfg, params = build()
    slot = ServingEngine(cfg, params, slots=1, max_len=48)
    with pytest.raises(TypeError, match="abort"):
        AsyncLMServer(slot)
    with pytest.raises(ValueError, match="admission"):
        AsyncLMServer(engine(cfg, params), admission="drop")
