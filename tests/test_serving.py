"""Serving engine: continuous batching, determinism, slot recycling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def mk_engine(name="deepseek-7b-smoke", slots=2, max_len=48):
    cfg = get_config(name)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, params, ServingEngine(cfg, params, slots=slots,
                                      max_len=max_len)


@pytest.mark.parametrize("name", ["deepseek-7b-smoke",
                                  "falcon-mamba-7b-smoke",
                                  "gemma2-9b-smoke",
                                  "zamba2-1.2b-smoke"])
def test_drains_all_requests(name, rng):
    cfg, params, eng = mk_engine(name)
    for i in range(5):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 8
                                               ).astype(np.int32),
                           max_new=5))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.tokens) == 5 for r in done)
    assert all(a is None for a in eng.active)


def test_greedy_matches_manual_decode(rng):
    """Engine greedy decode == hand-rolled prefill+decode loop."""
    cfg, params, eng = mk_engine(slots=1)
    m = build_model(cfg)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=6))
    got = eng.run()[0].tokens

    caches = m.init_cache(1, 48)
    lg, state = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                          caches)
    want = [int(jnp.argmax(lg[0]))]
    for t in range(5):
        lg, state = m.decode_step(params, jnp.asarray([want[-1]], jnp.int32),
                                  state, jnp.int32(8 + t))
        want.append(int(jnp.argmax(lg[0])))
    assert got == want


def test_mixed_lengths_and_recycling(rng):
    """Short requests finish first and their slots are reused."""
    cfg, params, eng = mk_engine(slots=2)
    lens = [2, 9, 3, 7, 2]
    for i, n in enumerate(lens):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 6
                                               ).astype(np.int32),
                           max_new=n))
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    for r, n in zip(sorted(done, key=lambda r: r.uid), lens):
        assert len(r.tokens) == n


def test_temperature_sampling_per_request_seeds(rng):
    """Sampling keys are per-request (SamplingParams.seed), not a shared
    engine stream: distinct seeds on the same prompt diverge, and the same
    seed reproduces the identical stream — co-batched or re-served."""
    from repro.serving import SamplingParams
    cfg, params, eng = mk_engine(slots=4)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=8,
                       sampling=SamplingParams(temperature=1.5, seed=1)))
    eng.submit(Request(uid=1, prompt=prompt, max_new=8,
                       sampling=SamplingParams(temperature=1.5, seed=2)))
    eng.submit(Request(uid=2, prompt=prompt, max_new=8,
                       sampling=SamplingParams(temperature=1.5, seed=1)))
    done = {r.uid: r.tokens for r in eng.run()}
    assert done[0] != done[1]         # distinct seeds: overwhelmingly likely
    assert done[0] == done[2]         # same seed: exactly reproducible


def test_greedy_tie_break_lowest_index():
    """Greedy serving breaks exact logit ties to the lowest token id —
    explicitly, not via backend-defined argmax behaviour."""
    from repro.serving.engine import _EngineBase
    assert _EngineBase.greedy_token(jnp.zeros((9,))) == 0
    assert _EngineBase.greedy_token(jnp.asarray([0.0, 3.0, 3.0, 1.0])) == 1
    assert _EngineBase.greedy_token(jnp.asarray([-1.0, -5.0, -1.0])) == 0


def test_eos_stops_early(rng):
    cfg, params, eng = mk_engine(slots=1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # discover the greedy first token, then use it as "eos"
    eng.submit(Request(uid=0, prompt=prompt, max_new=6))
    first = eng.run()[0].tokens[0]
    cfg, params, eng2 = mk_engine(slots=1)
    eng2.submit(Request(uid=1, prompt=prompt, max_new=6, eos_id=first))
    out = eng2.run()[0]
    assert out.tokens[0] == first and len(out.tokens) == 1
