"""Tensor-parallel sharded serving: mesh-vs-single-device identity.

The contract under test (docs/architecture.md "Tensor-parallel sharded
serving"): an ``EngineCore(mesh=N)`` shards only the page pool's KV-head
axis and runs the ragged step under shard_map — every device attends its
head band against its local pool shard and one tiled all-gather rebuilds
the head axis.  Because the gather is pure data movement (no cross-device
float arithmetic), the engine must be *token-identical* to the
single-device engine on the same request trace — greedy and seeded, float
and int8 pools, prefix cache on or off — and all host-side page
accounting (free heap, refcounts, per-request tables) must be
mesh-oblivious.  mesh=1 must not merely agree: it must lower to the very
same jaxpr as mesh=None (no shard_map wrapper in the graph).

Multi-chip cases run in a subprocess with forced host devices (the main
pytest process keeps 1 device); see tests/_multidevice.py.
"""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.serving import EngineCore
from tests._multidevice import run_with_devices
from tests.test_engine_core import build, _sampling_args


def _run(snippet: str) -> str:
    """Prepend the shared harness (column-0) to a dedented test body."""
    return run_with_devices(_COMMON + textwrap.dedent(snippet), n_devices=4)

# Shared subprocess preamble: a self-contained smoke serve() harness.
_COMMON = """
import numpy as np
import jax
from repro.configs import get_config
from repro.models import build_model
from repro.serving import EngineCore, Request
from repro.serving.sampling import SamplingParams

def build(**replace):
    cfg = get_config("deepseek-7b-smoke")
    if replace:
        cfg = cfg.replace(**replace)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params

def prompts(cfg, seed=7, lens=(5, 12, 20, 3)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, lp).astype(np.int32)
            for lp in lens]

def serve(cfg, params, mesh, *, prefix_cache=False, sampling=None):
    eng = EngineCore(cfg, params, lanes=3, page_size=8, num_pages=32,
                     chunk_size=8, mesh=mesh, prefix_cache=prefix_cache)
    for i, p in enumerate(prompts(cfg)):
        sp = None if sampling is None else SamplingParams(**sampling)
        eng.submit(Request(uid=i, prompt=p, max_new=6, sampling=sp))
    done = {r.uid: tuple(r.tokens) for r in eng.run()}
    return done, eng

def pool_state(eng):
    return (eng.kv.ref, sorted(eng.kv.free), eng.page_tables)
"""


# --------------------------------------------------- multi-chip identity --

def test_mesh_2_and_4_token_identity_and_pool_invariance():
    """Greedy mixed prefill+decode streams at mesh 1/2/4 emit identical
    token streams, identical host-side page accounting (free heap,
    refcounts, live tables — the page table is host-global, never
    sharded), the same number of step traces (O(1) compiles per width
    bucket, mesh-independent), and the analytic per-token collective
    bytes match Hq·Dh·layers·itemsize·(N−1)/N."""
    out = _run("""
        cfg, params = build(num_heads=4, num_kv_heads=4)
        d1, e1 = serve(cfg, params, None)
        d2, e2 = serve(cfg, params, 2)
        d4, e4 = serve(cfg, params, 4)
        assert d2 == d1 and d4 == d1, (d1, d2, d4)
        assert pool_state(e2) == pool_state(e1) == pool_state(e4)
        assert e1.trace_count == e2.trace_count == e4.trace_count
        assert (e1.mesh_size, e2.mesh_size, e4.mesh_size) == (1, 2, 4)
        # the gather moves the f32 attention-output activation, not a
        # cfg.dtype (bf16) value — itemsize 4 (see measure_collective_bytes)
        per_layer = cfg.num_heads * cfg.d_head * 4
        assert e1.collective_bytes_per_token == 0
        assert e2.collective_bytes_per_token == cfg.num_layers * per_layer // 2
        assert e4.collective_bytes_per_token == cfg.num_layers * per_layer * 3 // 4
        print("OK")
    """)
    assert "OK" in out


def test_mesh_2_gqa_identity_float_and_int8():
    """GQA (Hq=4, Hkv=2) at mesh 2 — each device holds one KV head serving
    two query heads, so the band slice must preserve the group ratio —
    token-identical for both the float and the int8-quantised pool."""
    out = _run("""
        for kv_quant in (False, True):
            cfg, params = build(kv_quant=kv_quant)
            a, _ = serve(cfg, params, None)
            b, _ = serve(cfg, params, 2)
            assert a == b, (kv_quant, a, b)
        print("OK")
    """)
    assert "OK" in out


def test_mesh_2_measured_collective_bytes_cross_check():
    """The *measured* collective accounting — per-device wire bytes walked
    out of the compiled ragged step's optimized HLO — must agree with the
    analytic model: every packed stream row (live or dead) runs the
    per-layer head all-gather, so ``measure_collective_bytes(width=t)``
    ≈ ``collective_bytes_per_token × t``.  Off-mesh it is exactly 0, and
    the number lands in the ``collective_bytes_per_step`` gauge (the
    registry feeds ``/metrics`` and the sharded bench family)."""
    out = _run("""
        cfg, params = build(num_heads=4, num_kv_heads=4)
        _, e1 = serve(cfg, params, None)
        assert e1.measure_collective_bytes() == 0
        assert e1.obs.registry.value("collective_bytes_per_step") == 0
        _, e2 = serve(cfg, params, 2)
        t = 16
        measured = e2.measure_collective_bytes(width=t)
        analytic = e2.collective_bytes_per_token * t
        assert measured > 0 and analytic > 0, (measured, analytic)
        err = abs(measured - analytic) / analytic
        assert err <= 0.05, (measured, analytic, err)
        assert e2.obs.registry.value("collective_bytes_per_step") == measured
        print("OK", measured, analytic)
    """)
    assert "OK" in out


def test_mesh_2_seeded_sampling_identity():
    """Seeded stochastic sampling is a deterministic function of the
    (replicated) logits, so the sampled streams must also be identical —
    the all-gather hands every device the full head axis before the
    unembed."""
    out = _run("""
        cfg, params = build()
        samp = dict(temperature=0.8, top_k=3, top_p=0.9, seed=42)
        a, _ = serve(cfg, params, None, sampling=samp)
        b, _ = serve(cfg, params, 2, sampling=samp)
        assert a == b, (a, b)
        print("OK")
    """)
    assert "OK" in out


def test_mesh_2_prefix_cache_identity():
    """The radix prefix cache is host-global: a genuinely shared prefix
    publishes, full- and partial-page hits grant the same page ids, and
    the copy-on-write page copy runs on the *sharded* pool (a jitted
    leaf-wise copy that must preserve each leaf's sharding) — all with
    token streams and pool accounting identical to single-device."""
    out = _run("""
        cfg, params = build()
        rng = np.random.default_rng(3)
        ps = 8
        shared = rng.integers(0, cfg.vocab_size, 2 * ps).astype(np.int32)
        # 0 publishes cold; 1-2 re-hit the full shared pages; 3 ends
        # mid-page -> partial hit -> CoW on the sharded pool
        ps_prompts = [np.concatenate(
            [shared, [i], rng.integers(0, cfg.vocab_size, 4)])
            .astype(np.int32) for i in range(3)] + [shared[:12]]

        def warm(mesh):
            eng = EngineCore(cfg, params, lanes=2, page_size=ps,
                             num_pages=32, chunk_size=ps, mesh=mesh,
                             prefix_cache=True)
            eng.submit(Request(uid=0, prompt=ps_prompts[0], max_new=5))
            eng.run()
            for i in (1, 2, 3):
                eng.submit(Request(uid=i, prompt=ps_prompts[i], max_new=5))
            eng.run()
            return {r.uid: tuple(r.tokens) for r in eng.finished}, eng

        a, ea = warm(None)
        b, eb = warm(2)
        assert a == b, (a, b)
        assert pool_state(ea) == pool_state(eb)
        assert ea.prefix_stats == eb.prefix_stats
        assert ea.prefix_stats["hits"] >= 3 and ea.kv.cow_copies >= 1, \
            (ea.prefix_stats, ea.kv.cow_copies)
        print("OK")
    """)
    assert "OK" in out


# --------------------------------------------------- mesh=1 == no mesh --

def test_mesh_one_lowers_to_the_single_device_jaxpr():
    """mesh=1 is a no-op, not a 1-device shard_map: the ragged step of an
    ``EngineCore(mesh=1)`` traces to the *same jaxpr string* as
    ``mesh=None`` — no shard_map/collective wrapper anywhere in the
    graph."""
    cfg, params = build()
    lanes, t, pw = 3, 16, 4

    def jaxpr_of(mesh):
        eng = EngineCore(cfg, params, lanes=lanes, page_size=8,
                         num_pages=32, chunk_size=8, mesh=mesh)
        cu = jnp.asarray([0, 1, 2, t, t], jnp.int32)
        return str(jax.make_jaxpr(eng._ragged)(
            eng.params, eng.kv.pool,
            jnp.full((t, pw), eng.kv.scratch, jnp.int32),
            jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
            jnp.zeros((lanes,), jnp.int32), cu, *_sampling_args(lanes)))

    assert jaxpr_of(1) == jaxpr_of(None)
    assert "shard_map" not in jaxpr_of(1)


def test_mesh_validation():
    """Constructor-time errors, never mid-serve: a mesh wider than the
    visible devices, a mesh that does not divide the head counts, and the
    padded (oracle) mode are all rejected with a clear message."""
    cfg, params = build()
    with pytest.raises(ValueError, match="devices visible|only"):
        EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                   mesh=1 + len(jax.devices()))

    out = _run("""
        cfg, params = build()       # num_heads=4, num_kv_heads=2
        try:
            EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                       mesh=4)
            raise SystemExit("no divisibility error")
        except ValueError as e:
            assert "divide" in str(e), e
        try:
            EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                       mesh=2, mode="padded")
            raise SystemExit("no mode error")
        except ValueError as e:
            assert "ragged" in str(e), e
        print("OK")
    """)
    assert "OK" in out
