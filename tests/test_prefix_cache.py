"""Shared-prefix KV reuse: radix cache + copy-on-write page pool (PR-5 gate).

The contracts this suite pins:

- **Token identity.**  Serving a shared-system-prompt workload through the
  prefix cache emits exactly the token streams of cold (cache-off) runs —
  float and int8, ragged and padded packings, across partial-page hits
  (CoW) and preemption/resume.  The cached pages hold the *same* KV rows
  the skipped prefill chunks would have written, so nothing downstream can
  tell the difference.
- **No prefill work for reused tokens.**  A warm request traces no new step
  function and streams only its cold tokens: the engine's compile counter
  stays flat and the per-step row accounting (`live_rows`/`padded_rows`)
  shows width-1 steps where the cold run streamed whole chunks.
- **Pool safety.**  Refcounts make sharing safe: the free heap never holds
  a referenced page, evicting one request never frees another's shared
  prefix (the double-free regression), CoW isolates writers from the cached
  original, and arbitrary interleavings of alloc/share/CoW/release/evict —
  driven through the real scheduler under shared-prefix load — preserve
  refcounts ≥ 0, free ∩ resident = ∅, lowest-id-first allocation and
  conservation of total pages.
- **Radix mechanics.**  Page-aligned block matching with partial-page lcp
  extension, the known−1 cap (one token always left to sample from), LRU
  leaf-first eviction, and the `max_pages` budget.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.models import build_model
from repro.serving import (EngineCore, PagedKVCache, RadixPrefixCache,
                           Request, Scheduler)
from tests.test_engine_core import build, by_uid, prompts_for

PS = 8   # page size used throughout (matches the smoke pool tests)


def drain(eng, max_steps=2000):
    """Step to empty → (per-uid token streams, outputs); bounded so a
    scheduling livelock fails the test instead of hanging it."""
    outs = []
    while eng.scheduler.has_work():
        outs.append(eng.step())
        assert len(outs) < max_steps, "engine did not drain"
    return by_uid(eng.finished), outs


def check_pool(kv: PagedKVCache, cache: RadixPrefixCache = None,
               running=()) -> None:
    """The pool invariants every interleaving must preserve."""
    free = list(kv.free)
    assert len(set(free)) == len(free), "duplicate page on the free heap"
    assert all(kv.ref[p] == 0 for p in free), \
        "free heap holds a referenced page"
    assert all(r >= 0 for r in kv.ref), "negative refcount"
    held = sum(1 for r in kv.ref if r > 0)
    assert len(free) + held == kv.num_pages, "pages leaked or double-freed"
    if cache is not None:
        assert all(kv.ref[n.page] >= 1 for n in cache._nodes.values()), \
            "cached node holds a freed page"
        assert kv.available_pages == len(free) + cache.reclaimable_pages
    for run in running:
        assert all(kv.ref[p] >= 1 for p in run.pages), \
            "resident request holds a freed page"


def checked_alloc(kv: PagedKVCache) -> None:
    """Wrap ``kv.alloc`` to assert lowest-id-first allocation."""
    orig = PagedKVCache.alloc

    def alloc():
        expect = min(kv.free) if kv.free else None
        page = orig(kv)
        if expect is not None:
            assert page == expect, f"alloc {page}, lowest free was {expect}"
        return page

    kv.alloc = alloc


# ------------------------------------------------------------ radix tree --

def _kv_and_cache(num_pages=16, page_size=4, max_pages=None):
    cfg, _ = build()
    kv = PagedKVCache(build_model(cfg), num_pages, page_size)
    return kv, RadixPrefixCache(kv, max_pages=max_pages)


def test_radix_match_full_partial_and_cap():
    """Block-aligned matching: full-page walks, partial-page lcp extension,
    and the known−1 cap that always leaves one token to stream."""
    kv, cache = _kv_and_cache(page_size=4)
    toks = np.arange(100, 110, dtype=np.int32)          # 10 tokens
    pages = [kv.alloc(), kv.alloc()]                    # rows 0..7 (2 pages)
    assert cache.insert(toks[:8], pages) == 2
    kv.release(pages)                                   # cache refs keep them

    full = cache.match(toks)                            # limit 9 → 2 pages
    assert (full.tokens, full.partial_rows) == (8, 0)
    assert full.pages == (0, 1)

    part = cache.match(toks[:8])        # limit 7: 1 full + 3-row partial
    assert (part.tokens, part.partial_rows) == (7, 3)
    assert part.pages == (0, 1)

    assert cache.match(toks[:5]).tokens == 4            # 1 full, no partial
    assert cache.match(toks[:2]).tokens == 1            # pure partial
    assert cache.match(np.array([7, 8, 9, 10, 11], np.int32)).tokens == 0

    # a match is pure: nothing granted, nothing stamped, no stats
    assert all(kv.ref[p] == 1 for p in (0, 1))
    assert cache.lookups == 0

    cache.grant(part, total_tokens=8)
    assert all(kv.ref[p] == 2 for p in (0, 1))
    assert (cache.hits, cache.hit_tokens, cache.partial_hits) == (1, 7, 1)
    check_pool(kv, cache)


def test_radix_lru_leaf_first_eviction_and_budget():
    """Eviction reclaims LRU *leaves* only (never stranding descendants),
    skips request-pinned pages, and ``max_pages`` caps the footprint."""
    kv, cache = _kv_and_cache(page_size=4)
    toks = np.arange(50, 62, dtype=np.int32)            # 3 full blocks
    pages = [kv.alloc() for _ in range(3)]
    cache.insert(toks, pages)
    kv.release(pages)
    assert cache.cached_pages == cache.reclaimable_pages == 3

    # leaf-first: the chain must come back deepest-first, 2 then 1 then 0
    assert cache.evict_one() and sorted(kv.free)[:1] == [2]
    assert cache.evict_one() and 1 in kv.free

    # re-publish depth 1, then pin the whole path as a request grant would
    page1 = kv.alloc()
    cache.insert(toks[:8], [0, page1])                  # 0 still cached
    kv.release_one(page1)                               # cache ref keeps it
    hit = cache.match(toks[:9])
    assert hit.pages == (0, page1)
    cache.grant(hit, total_tokens=9)
    assert cache.reclaimable_pages == 0
    assert not cache.evict_one()                        # everything pinned
    for p in hit.pages:
        kv.release_one(p)
    assert cache.reclaimable_pages == 2

    # budget: enforce down to 1 resident cached page (LRU leaf goes first)
    cache.max_pages = 1
    cache.enforce_budget()
    assert cache.cached_pages == 1
    check_pool(kv, cache)


def test_pool_primitive_edges():
    """share/release/cow edge semantics the scheduler relies on."""
    kv, cache = _kv_and_cache(num_pages=4, page_size=4)
    p = kv.alloc()
    assert p == 0 and kv.ref[0] == 1
    kv.share(p)
    kv.release_one(p)
    assert kv.ref[p] == 1 and p not in kv.free          # still referenced
    kv.release_one(p)
    assert p in kv.free
    with pytest.raises(ValueError, match="double release"):
        kv.release_one(p)
    with pytest.raises(ValueError, match="share of unreferenced"):
        kv.share(p)

    q = kv.alloc()
    assert kv.cow(q) == q                               # exclusive: in place
    kv.share(q)
    r = kv.cow(q)                                       # shared: fresh copy
    assert r != q and kv.ref[q] == 1 and kv.ref[r] == 1
    assert kv.cow_copies == 1
    check_pool(kv, cache)


# ------------------------------------------------- double-free regression --

def test_eviction_never_frees_shared_prefix_pages():
    """The double-free regression: two residents share cached prefix pages;
    evicting one must not free them — the survivor keeps decoding through
    the shared pages and stays token-identical to its uncontended run."""
    cfg, params = build()
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, 2 * PS).astype(np.int32)
    tails = [np.concatenate([[i], rng.integers(0, cfg.vocab_size, 3)])
             .astype(np.int32) for i in range(3)]

    def engine(num_pages, prefix_cache=True):
        return EngineCore(cfg, params, lanes=2, page_size=PS,
                          num_pages=num_pages, chunk_size=PS,
                          prefix_cache=prefix_cache)

    # uncontended truths (cache off = pure cold compute)
    want = {}
    for uid, tail in enumerate(tails):
        eng = engine(16, prefix_cache=False)
        eng.submit(Request(uid=uid, prompt=np.concatenate([shared, tail]),
                           max_new=(14, 14, 4)[uid]))
        want.update(drain(eng)[0])

    # contended: seed the cache, then two sharers fight over a small pool
    # (peak distinct demand is 2 shared + 3 + 2 exclusive pages = 7 > 6)
    eng = engine(6)
    eng.submit(Request(uid=2, prompt=np.concatenate([shared, tails[2]]),
                       max_new=4))
    drain(eng)                                  # publishes the shared prefix
    eng.submit(Request(uid=0, prompt=np.concatenate([shared, tails[0]]),
                       max_new=14))
    eng.submit(Request(uid=1, prompt=np.concatenate([shared, tails[1]]),
                       max_new=14))
    preempted = []
    shared_pages = None
    while eng.scheduler.has_work():
        out = eng.step()
        preempted.extend(out.preempted)
        runs = {r.req.uid: r for r in eng.scheduler.running}
        if shared_pages is None and 0 in runs and 1 in runs:
            a, b = runs[0].pages, runs[1].pages
            shared_pages = [p for p in a if p in b]
        if preempted and 0 in runs:
            # the survivor's pages are all alive, nothing shared was freed
            assert all(eng.kv.ref[p] >= 1 for p in runs[0].pages)
            assert not any(p in eng.kv.free for p in runs[0].pages)
        check_pool(eng.kv, eng.prefix_cache, eng.scheduler.running)
    assert shared_pages, "the requests never actually shared prefix pages"
    assert preempted, "pool contention never evicted a sharer"
    got, _ = drain(eng)
    assert {u: want[u] for u in got} == got, \
        "eviction of a sharer corrupted a shared prefix"
    check_pool(eng.kv, eng.prefix_cache)


def test_partial_hit_on_tight_pool_does_not_livelock():
    """Regression: a partial-page hit whose CoW budget ignored the page the
    copy gives back would demand pages the pool cannot produce, find no
    victim (the request is alone), and wedge the lane forever.  The CoW
    credit must let a workload that physically fits drain — and with the
    pool *completely* pinned, the cache must yield sole ownership of the
    shared page rather than starve the lane."""
    cfg, params = build()
    rng = np.random.default_rng(2)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    follow = np.concatenate(
        [base[:6], rng.integers(0, cfg.vocab_size, 8)]).astype(np.int32)

    def streams(**kw):
        # pool of 4 × 4-row pages: follow needs all 4 worst-case
        eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=4,
                         chunk_size=16, **kw)
        eng.submit(Request(uid=0, prompt=base, max_new=1))
        got, _ = drain(eng)
        eng.submit(Request(uid=1, prompt=follow, max_new=1))
        got2, _ = drain(eng)                 # must not wedge (run() bounds)
        check_pool(eng.kv, eng.prefix_cache)
        return {**got, **got2}

    assert streams(prefix_cache=True) == streams(prefix_cache=False)


# ----------------------------------------------- interleaving properties --

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_pool_invariants_under_shared_prefix_interleavings(seed):
    """Arbitrary interleavings of alloc / share / CoW / release / evict —
    generated by driving the real scheduler over a random shared-prefix
    request stream on a pool far too small for it — preserve the pool
    invariants at every step: refcounts ≥ 0, free ∩ resident = ∅,
    lowest-id-first allocation, conservation of total pages, and the
    available-page accounting the admission path trusts."""
    from tests.test_engine_core import _sim_engine

    rng = np.random.default_rng(seed)
    cfg, _ = build()
    kv = PagedKVCache(build_model(cfg), 10, 4)
    checked_alloc(kv)
    cache = RadixPrefixCache(
        kv, max_pages=int(rng.integers(2, 9)) if rng.random() < 0.5
        else None)
    sched = Scheduler(kv, lanes=3, chunk_size=4, prefix_cache=cache)

    # a few base prefixes; most requests extend one of them (radix hits,
    # shared grants, CoW on the partial pages), some are fresh streams
    bases = [rng.integers(0, 40, int(rng.integers(4, 14))).astype(np.int32)
             for _ in range(3)]
    uid = 0
    for _ in range(int(rng.integers(4, 9))):
        if rng.random() < 0.75:
            base = bases[int(rng.integers(0, len(bases)))]
            tail = rng.integers(0, 40, int(rng.integers(1, 6)))
            prompt = np.concatenate([base, tail]).astype(np.int32)
        else:
            prompt = rng.integers(0, 40,
                                  int(rng.integers(1, 16))).astype(np.int32)
        sched.submit(Request(uid=uid, prompt=prompt,
                             max_new=int(rng.integers(1, 8))))
        uid += 1

    steps = 0
    while sched.has_work():
        steps += 1
        assert steps < 2000, "scheduler did not drain"
        if rng.random() < 0.5:
            batch, _ = sched.schedule_ragged()
            plans = batch.plans
        else:
            plans, _ = sched.schedule()
            batch = sched.pack(plans)
        check_pool(kv, cache, sched.running)
        if rng.random() < 0.15:                 # pressure from outside too
            cache.evict_one()
            check_pool(kv, cache, sched.running)
        _sim_engine(sched, batch)
    # drained: every page is either free or held by the cache alone
    check_pool(kv, cache)
    assert all(kv.ref[n.page] == 1 for n in cache._nodes.values())
    assert len(kv.free) + cache.cached_pages == kv.num_pages
    if cache.max_pages is not None:
        assert cache.cached_pages <= cache.max_pages


# ------------------------------------------------------- token identity --

@pytest.mark.parametrize("mode", ["ragged", "padded"])
@pytest.mark.parametrize("kv_quant", [False, True])
def test_shared_prefix_serving_token_identical(kv_quant, mode):
    """N requests reusing one system prompt: token streams identical to the
    cold (cache-off) engine, with *exact* ``prefix_hit_tokens`` accounting
    — the shared prefix is page-aligned, each tail opens with a distinct
    token, so every warm admission hits exactly the prefix."""
    cfg, params = build(kv_quant=kv_quant)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 3 * PS).astype(np.int32)
    tails = [np.concatenate([[i], rng.integers(0, cfg.vocab_size, n)])
             .astype(np.int32) for i, n in enumerate((5, 9, 7, 4))]
    news = (6, 4, 8, 5)

    def serve(prefix_cache):
        eng = EngineCore(cfg, params, lanes=2, page_size=PS, num_pages=32,
                         chunk_size=PS, mode=mode, prefix_cache=prefix_cache)
        # request 0 cold-fills the cache; 1..3 arrive once it published
        eng.submit(Request(uid=0, prompt=np.concatenate([shared, tails[0]]),
                           max_new=news[0]))
        _, outs = drain(eng)
        for uid in (1, 2, 3):
            eng.submit(Request(
                uid=uid, prompt=np.concatenate([shared, tails[uid]]),
                max_new=news[uid]))
        _, outs2 = drain(eng)
        return (by_uid(eng.finished), outs + outs2,
                eng.prefix_stats.get("hit_tokens", 0))

    want, _, _ = serve(False)
    got, outs, hit_tokens = serve(True)
    assert got == want, "cache-hit serving diverged from cold prefill"
    # exact accounting: three warm admissions × the 24-token shared prefix
    assert hit_tokens == 3 * len(shared)
    assert sum(o.prefix_hit_tokens for o in outs) == 3 * len(shared)


@pytest.mark.parametrize("mode", ["ragged", "padded"])
def test_hit_serving_survives_preemption_resume(mode):
    """Cache on + a pool too small for the offered load: the victim's full
    pages are published at eviction, its resume admission re-hits them (or
    recomputes if they were reclaimed), and every stream stays identical
    to the uncontended runs.  Both packings."""
    cfg, params = build()
    specs = [(4, 26), (12, 14)]
    prompts = prompts_for(cfg, 21, [lp for lp, _ in specs])

    solo = {}
    for uid, (lp, mn) in enumerate(specs):
        eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=16,
                         chunk_size=4, mode=mode)
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
        solo[uid] = eng.run()[0].tokens

    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=8,
                     chunk_size=4, mode=mode, prefix_cache=True)
    for uid, (lp, mn) in enumerate(specs):
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
    preempted = []
    while eng.scheduler.has_work():
        preempted.extend(eng.step().preempted)
        check_pool(eng.kv, eng.prefix_cache, eng.scheduler.running)
    assert preempted, "pool contention never triggered an eviction"
    assert by_uid(eng.finished) == solo, \
        "preempted request did not resume token-identically under the cache"


def test_resume_by_cache_hit():
    """With headroom for the victim's published pages to survive, resuming
    a preempted request is a cache hit, not a recompute: the resume
    admission grants its own previously-written pages back."""
    cfg, params = build()
    specs = [(4, 30), (16, 10)]
    prompts = prompts_for(cfg, 5, [lp for lp, _ in specs])
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=11,
                     chunk_size=4, prefix_cache=True)
    for uid, (lp, mn) in enumerate(specs):
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
    preempted, hit_tokens = [], 0
    while eng.scheduler.has_work():
        out = eng.step()
        preempted.extend(out.preempted)
        hit_tokens += out.prefix_hit_tokens
    assert preempted, "no eviction — shrink the pool"
    assert hit_tokens > 0, "resume never hit the published prefix"
    # and the streams still match a cold, uncontended run
    for uid, (lp, mn) in enumerate(specs):
        solo = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=16,
                          chunk_size=4)
        solo.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
        assert solo.run()[0].tokens == by_uid(eng.finished)[uid]


def test_cow_isolates_writers_from_cached_pages():
    """Partial-page hits copy-on-write: a request that writes into the
    middle of a cached page gets a private copy, and the original page
    still serves later exact-prefix requests bit-identically."""
    cfg, params = build()
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)  # 2⅝ pages

    def cold(uid, prompt):
        eng = EngineCore(cfg, params, lanes=1, page_size=PS, num_pages=32,
                         chunk_size=PS)
        eng.submit(Request(uid=uid, prompt=prompt, max_new=4))
        return drain(eng)[0][uid]

    eng = EngineCore(cfg, params, lanes=1, page_size=PS, num_pages=32,
                     chunk_size=PS, prefix_cache=True)

    def warm(uid, prompt):
        eng.submit(Request(uid=uid, prompt=prompt, max_new=4))
        return drain(eng)[0][uid]

    assert warm(0, base) == cold(0, base)          # publishes 21-row prefix
    # prefix of the cached stream ending mid-page: 1 full page + 6-row
    # partial hit, CoW before its first generated row lands
    assert warm(1, base[:14]) == cold(1, base[:14])
    assert eng.kv.cow_copies >= 1, "partial-page hit never copied"
    # the cached original must be untouched: an exact re-serve still matches
    assert warm(2, base) == cold(2, base)
    check_pool(eng.kv, eng.prefix_cache)


def test_mid_prefill_abort_publishes_only_committed_pages():
    """Publish cursor-clamp regression: abort a request mid-prefill, at a
    row count that is NOT page-aligned, so its table's last page holds
    granted-but-unwritten rows.  Only pages whose *every* row the engine
    committed may reach the radix cache — a leaked partial page would
    serve garbage KV rows to the next request sharing the prefix.  The
    proof is end-to-end: a later identical prompt through the warm cache
    must be token-identical to a cold engine."""
    cfg, params = build()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, 3 * PS + 4).astype(np.int32)

    def cold(uid):
        eng = EngineCore(cfg, params, lanes=1, page_size=PS, num_pages=32,
                         chunk_size=PS)
        eng.submit(Request(uid=uid, prompt=prompt, max_new=4))
        return drain(eng)[0][uid]

    # chunk 12 straddles the 8-row page: one step commits page 0 fully and
    # page 1 halfway — the abort lands with rows=12, table covering 16
    eng = EngineCore(cfg, params, lanes=1, page_size=PS, num_pages=32,
                     chunk_size=12, prefix_cache=True)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    eng.step()
    run = eng.scheduler.running[0]
    rows = run.rows
    assert 0 < rows < len(prompt) and rows % PS != 0, \
        "abort point must be mid-prefill and mid-page"
    assert len(run.pages) > rows // PS, \
        "table must already cover granted-but-unwritten rows"
    assert eng.abort(0)
    check_pool(eng.kv, eng.prefix_cache)
    assert eng.prefix_cache.cached_pages == rows // PS, \
        "abort published a page past the committed cursor"

    # the warm re-serve hits exactly the committed pages and matches cold
    eng.submit(Request(uid=1, prompt=prompt, max_new=4))
    got, outs = drain(eng)
    assert sum(o.prefix_hit_tokens for o in outs) == (rows // PS) * PS, \
        "the committed page was never reused — test is vacuous"
    assert got[1] == cold(1), \
        "a partially-written published page corrupted the warm stream"
    check_pool(eng.kv, eng.prefix_cache)


# ------------------------------------------- no-prefill-work guarantee --

@pytest.mark.parametrize("kv_quant", [False, True])
def test_hit_path_skips_prefill_compute(kv_quant):
    """The reused prefix provably costs no prefill compute: serving the
    same prompt warm (a) traces no new step function (compile counter
    flat), and (b) executes only width-1 steps — the row accounting shows
    one live token per step, never a prefill chunk, and total computed
    rows equal the cold tokens alone (known − hit), not the prompt."""
    cfg, params = build(kv_quant=kv_quant)
    prompt = prompts_for(cfg, 3, (3 * PS,))[0]          # 24 tokens
    eng = EngineCore(cfg, params, lanes=1, page_size=PS, num_pages=32,
                     chunk_size=PS, prefix_cache=True)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    _, cold_outs = drain(eng)
    cold_tokens = eng.finished[0].tokens
    eng.finished.clear()
    traced = eng.trace_count
    assert sum(o.live_rows for o in cold_outs) == len(prompt) + 3

    eng.submit(Request(uid=1, prompt=prompt, max_new=4))
    _, outs = drain(eng)
    assert eng.trace_count == traced, \
        "the hit path traced a new step function"
    hit = sum(o.prefix_hit_tokens for o in outs)
    assert hit == len(prompt) - 1                       # known − 1 cap
    # every warm step is a width-1 sampling step: no prefill rows anywhere
    # (the single cold token is the degenerate chunk of one — a decode)
    assert [o.live_rows for o in outs] == [1] * 4
    assert [o.padded_rows for o in outs] == [1] * 4
    assert sum(o.prefill_tokens for o in outs) == 0
    assert sum(o.decode_tokens for o in outs) == 4
    assert eng.finished[0].tokens == cold_tokens
