"""Streaming (fine-grained-pipelined) attention vs the materialised oracle,
plus the paper's O(l)-memory guarantee asserted on the jaxpr."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streaming_attention import naive_attention, streaming_attention


def mk(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


CASES = [
    dict(hq=4, hkv=4, lq=64, lkv=64, d=16, causal=True),
    dict(hq=8, hkv=2, lq=48, lkv=48, d=32, causal=True),             # GQA
    dict(hq=4, hkv=1, lq=33, lkv=33, d=8, causal=True),              # MQA, odd
    dict(hq=4, hkv=4, lq=16, lkv=80, d=16, causal=True, q_offset=64),
    dict(hq=4, hkv=2, lq=64, lkv=64, d=16, causal=True, window=16),
    dict(hq=2, hkv=2, lq=40, lkv=40, d=16, causal=False, cap=30.0),
    dict(hq=2, hkv=2, lq=32, lkv=32, d=16, causal=True, exp_mode="exact"),
    dict(hq=2, hkv=2, lq=32, lkv=32, d=16, causal=True, exp_mode="lut0"),
]


@pytest.mark.parametrize("case", CASES)
def test_matches_naive(rng, case):
    c = dict(case)
    q = mk(rng, 2, c.pop("hq"), c.pop("lq"), c["d"])
    k = mk(rng, 2, c.pop("hkv"), c.pop("lkv"), c.pop("d"))
    v = mk(rng, *k.shape)
    em = c.pop("exp_mode", "lut")
    out = streaming_attention(q, k, v, block_k=16, exp_mode=em, **c)
    ref = naive_attention(q, k, v, exp_mode=em, **c)
    # lut0 (e^r≈1, 0.54% error) composes differently through the online
    # rescale vs the one-shot softmax — compare at its own error scale
    atol = 5e-3 if em == "lut0" else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=atol, rtol=1e-4)


def test_no_quadratic_intermediate(rng):
    """Paper §IV: the (Lq, Lkv) logit matrix must never exist.

    Checked on the jaxpr: no intermediate carries both full sequence dims
    (only (lq, block_k) tiles may appear)."""
    lq = lkv = 256
    block = 32
    q = mk(rng, 1, 2, lq, 16)
    k = mk(rng, 1, 2, lkv, 16)
    v = mk(rng, 1, 2, lkv, 16)

    jaxpr = jax.make_jaxpr(
        lambda a, b, c: streaming_attention(a, b, c, causal=True,
                                            block_k=block))(q, k, v)

    def has_quadratic(eqns):
        for eq in eqns:
            for var in list(eq.outvars):
                shape = getattr(var.aval, "shape", ())
                if sum(1 for s in shape if s == lq) >= 2:
                    return True
            for sub in eq.params.values():
                if hasattr(sub, "jaxpr"):
                    if has_quadratic(sub.jaxpr.eqns):
                        return True
        return False

    assert not has_quadratic(jaxpr.jaxpr.eqns), \
        "found an (L, L) intermediate — fine-grained pipelining violated"


def test_naive_does_materialise(rng):
    """Sanity for the test above: the baseline DOES build the (L, L) matrix."""
    lq = 256
    q = mk(rng, 1, 2, lq, 16)
    jaxpr = jax.make_jaxpr(
        lambda a: naive_attention(a, a, a, causal=True))(q)
    found = any(
        sum(1 for s in getattr(v.aval, "shape", ()) if s == lq) >= 2
        for eq in jaxpr.jaxpr.eqns for v in eq.outvars)
    assert found


def test_gradients_match_naive(rng):
    q = mk(rng, 1, 4, 32, 16)
    k = mk(rng, 1, 2, 32, 16)
    v = mk(rng, 1, 2, 32, 16)

    # exact-exp mode: the custom VJP must match autodiff-through-naive
    # tightly (pure flash-backward correctness, no LUT noise)
    gs = jax.grad(lambda q, k, v: jnp.sum(streaming_attention(
        q, k, v, causal=True, block_k=8, exp_mode="exact") ** 2),
        argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(lambda q, k, v: jnp.sum(naive_attention(
        q, k, v, causal=True, exp_mode="exact") ** 2),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gs, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-3)
    # lut mode: both paths approximate exp'' differently — loose agreement
    gs = jax.grad(lambda q: jnp.sum(streaming_attention(
        q, k, v, causal=True, block_k=8) ** 2))(q)
    gn = jax.grad(lambda q: jnp.sum(naive_attention(
        q, k, v, causal=True, exp_mode="lut") ** 2))(q)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gn),
                               atol=3e-2, rtol=5e-2)


def test_gradient_with_softcap_and_window(rng):
    q = mk(rng, 1, 2, 24, 8)
    k = mk(rng, 1, 2, 24, 8)
    v = mk(rng, 1, 2, 24, 8)
    kw = dict(causal=True, window=8, cap=20.0)

    gs = jax.grad(lambda q: jnp.sum(
        streaming_attention(q, k, v, block_k=8, exp_mode="exact", **kw)))(q)
    gn = jax.grad(lambda q: jnp.sum(
        naive_attention(q, k, v, exp_mode="exact", **kw)))(q)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gn),
                               atol=5e-4, rtol=1e-3)


def test_kv_len_masking(rng):
    """A partially-filled cache must equal attention over the valid prefix."""
    q = mk(rng, 1, 2, 4, 8)
    k_full = mk(rng, 1, 2, 32, 8)
    v_full = mk(rng, 1, 2, 32, 8)
    out = streaming_attention(q, k_full, v_full, causal=True, q_offset=16,
                              kv_len=20, block_k=8)
    ref = naive_attention(q, k_full[:, :, :20], v_full[:, :, :20],
                          causal=True, q_offset=16, exp_mode="lut")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_kv_pos_ring_equivalence(rng):
    """Ring-buffer semantics: shuffled slots + kv_pos == ordered cache."""
    lc = 16
    q = mk(rng, 1, 2, 1, 8)
    k = mk(rng, 1, 2, lc, 8)
    v = mk(rng, 1, 2, lc, 8)
    perm = np.asarray(rng.permutation(lc))
    kv_pos = jnp.asarray(perm[None, :], jnp.int32) + 4   # positions 4..19
    out = streaming_attention(q, k, v, causal=True, q_offset=19,
                              kv_pos=kv_pos, block_k=8)
    # reorder into position order and use the plain path
    order = np.argsort(perm)
    ref = streaming_attention(q, k[:, :, order], v[:, :, order], causal=True,
                              q_offset=19, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_lut_vs_exact_close(rng):
    """The LUT softmax changes attention outputs by < 1e-3 (paper accuracy)."""
    q = mk(rng, 1, 4, 64, 16)
    k = mk(rng, 1, 4, 64, 16)
    v = mk(rng, 1, 4, 64, 16)
    a = streaming_attention(q, k, v, causal=True, exp_mode="lut")
    b = streaming_attention(q, k, v, causal=True, exp_mode="exact")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-3
