"""Paged-KV serving engine: equivalence with the contiguous engine, page
lifecycle (free list, reuse after release), unsupported-layout rejection,
and the in-place decode guarantee (no gathered cache view in the graph)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import PagedServingEngine, Request, ServingEngine


def build(name="deepseek-7b-smoke", **replace):
    cfg = get_config(name)
    if replace:
        cfg = cfg.replace(**replace)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def mixed_requests(cfg, rng, lens=(3, 9, 5, 7, 2)):
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + (i % 3) * 3
                                        ).astype(np.int32),
                    max_new=n)
            for i, n in enumerate(lens)]


def by_uid(done):
    return {r.uid: r.tokens for r in done}


# ------------------------------------------------------------ equivalence --

def test_paged_matches_contiguous_greedy():
    """Greedy decode through the paged engine must be token-identical to the
    slot-contiguous engine — paging is a memory layout, not a model change."""
    cfg, params = build()
    out = {}
    for make in [
        lambda: ServingEngine(cfg, params, slots=2, max_len=64),
        lambda: PagedServingEngine(cfg, params, slots=2, page_size=8,
                                   num_pages=16),
    ]:
        eng = make()
        for r in mixed_requests(cfg, np.random.default_rng(7)):
            eng.submit(r)
        out[type(eng).__name__] = by_uid(eng.run())
    assert out["PagedServingEngine"] == out["ServingEngine"]


def test_paged_matches_contiguous_quantized_cache():
    """INT8 KV caches page too (values + per-row scales share page tables)."""
    cfg, params = build(kv_quant=True)
    outs = []
    for make in [
        lambda: ServingEngine(cfg, params, slots=2, max_len=64),
        lambda: PagedServingEngine(cfg, params, slots=2, page_size=8,
                                   num_pages=16),
    ]:
        eng = make()
        for r in mixed_requests(cfg, np.random.default_rng(3), lens=(4, 6, 3)):
            eng.submit(r)
        outs.append(by_uid(eng.run()))
    assert outs[0] == outs[1]


def test_prompt_crossing_page_boundaries():
    """Prompts longer than one page prefill into multiple pages correctly."""
    cfg, params = build()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)  # 3 pages

    eng = ServingEngine(cfg, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_new=6))
    want = eng.run()[0].tokens

    peng = PagedServingEngine(cfg, params, slots=1, page_size=8, num_pages=8)
    peng.submit(Request(uid=0, prompt=prompt.copy(), max_new=6))
    assert peng.run()[0].tokens == want


# ---------------------------------------------------------- page lifecycle --

def test_pages_released_and_reused():
    """All pages return to the free list after a wave drains, and a second
    wave reusing those physical pages decodes identically."""
    cfg, params = build()
    eng = PagedServingEngine(cfg, params, slots=2, page_size=8, num_pages=12)

    def wave():
        for r in mixed_requests(cfg, np.random.default_rng(7)):
            eng.submit(r)
        done = by_uid(eng.run())
        eng.finished.clear()
        return done

    first = wave()
    assert eng.pages_in_use == 0
    assert eng.kv.reserved == 0
    assert sorted(eng.kv.free) == list(range(12))
    second = wave()                     # same traffic over recycled pages
    assert second == first
    assert eng.pages_in_use == 0


def test_admission_waits_for_free_pages():
    """A pool too small for all requests at once still drains (FIFO waits
    for reservations to free) and never double-allocates a page."""
    cfg, params = build()
    # each request reserves ceil((7+8)/8) = 2 pages; pool of 4 → 2 resident
    eng = PagedServingEngine(cfg, params, slots=4, page_size=8, num_pages=4)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(7, dtype=np.int32) + i,
                           max_new=8))
    seen_overlap = []
    while eng.queue or any(a is not None for a in eng.active):
        eng.step()
        live_pages = [p for t in eng.page_tables for p in t]
        assert len(live_pages) == len(set(live_pages)), "page double-booked"
        seen_overlap.append(sum(a is not None for a in eng.active))
    assert len(eng.finished) == 5
    assert max(seen_overlap) <= 2       # pool capped concurrency, not slots
    assert eng.pages_in_use == 0


def test_lazy_page_growth():
    """Decode allocates pages only as the sequence crosses page boundaries."""
    cfg, params = build()
    eng = PagedServingEngine(cfg, params, slots=1, page_size=8, num_pages=8)
    eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                       max_new=12))   # reserves ceil(18/8)=3, starts with 1
    eng.step()
    assert len(eng.page_tables[0]) == 1          # 6-token prompt: one page
    for _ in range(4):
        eng.step()
    assert len(eng.page_tables[0]) == 2          # crossed row 8
    eng.run()
    assert eng.pages_in_use == 0


# ------------------------------------------------------- in-place decode --

def _jaxpr_shapes(jaxpr):
    """Every intermediate array shape in a jaxpr, nested subjaxprs included
    (pjit bodies, scan bodies, vmap — wherever the gather could hide)."""
    def sub(val):
        vals = val if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v

    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield tuple(aval.shape)
        for val in eqn.params.values():
            for j in sub(val):
                yield from _jaxpr_shapes(j)


@pytest.mark.parametrize("kv_quant", [False, True])
def test_decode_graph_has_no_gathered_view(kv_quant):
    """The paged decode step must never materialise the contiguous
    (B, …, width·page_size, …) cache view: every intermediate in the traced
    step graph is checked for the gathered-length dimension.  page_size=12
    with a 16-slot table makes that length 192 — longer than one attend
    block and a value no model/config dimension of the smoke config shares,
    so a hit can only be the gathered copy."""
    cfg, params = build(kv_quant=kv_quant)
    ps, width = 12, 16
    eng = PagedServingEngine(cfg, params, slots=2, page_size=ps,
                             num_pages=32)
    # a 150-row prompt owns 13 pages; the engine pads tables to width 16
    eng.submit(Request(uid=0,
                       prompt=(np.arange(150, dtype=np.int32)
                               % cfg.vocab_size),
                       max_new=4))
    eng.step()
    npages = len(eng.page_tables[0])
    assert npages == 13 and (1 << (npages - 1).bit_length()) == width
    tbl = np.full((2, width), eng.kv.scratch, np.int32)
    tbl[0, :npages] = eng.page_tables[0]
    gathered_len = width * ps                              # 192

    jaxpr = jax.make_jaxpr(eng._decode)(
        params, eng.kv.pool, jnp.asarray(tbl),
        jnp.zeros((2,), jnp.int32), jnp.asarray([150, 0], jnp.int32))
    bad = [s for s in _jaxpr_shapes(jaxpr.jaxpr) if gathered_len in s]
    assert not bad, f"gathered cache view in decode graph: {bad}"

    # sanity: the detector does catch the legacy gather copy
    legacy = jax.make_jaxpr(
        lambda pool: eng.kv.gather(pool, jnp.asarray(tbl)))(eng.kv.pool)
    assert any(gathered_len in s for s in _jaxpr_shapes(legacy.jaxpr))


# ------------------------------------------------------------- rejection --

@pytest.mark.parametrize("name,page_size", [
    ("gemma2-9b-smoke", 16),        # ring-buffer sliding-window local caches
    ("falcon-mamba-7b-smoke", 16),  # SSM state: no length axis to page
])
def test_unpageable_layouts_rejected(name, page_size):
    cfg, params = build(name)
    with pytest.raises(ValueError, match="paged KV cache"):
        PagedServingEngine(cfg, params, slots=2, page_size=page_size,
                           num_pages=8)
