"""Paged serving (EngineCore + the deprecated PagedServingEngine shim):
equivalence with the contiguous engine, page lifecycle (free list, reuse
after release, pool-capped traffic), structured unsupported-layout
rejection, and the in-place decode guarantee (no gathered cache view in
the step graph)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EngineCore, PagedServingEngine, Request,
                           ServingEngine, UnsupportedCacheLayout)

warnings.filterwarnings("ignore", category=DeprecationWarning,
                        module="repro.serving.engine")


def build(name="deepseek-7b-smoke", **replace):
    cfg = get_config(name)
    if replace:
        cfg = cfg.replace(**replace)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def mixed_requests(cfg, rng, lens=(3, 9, 5, 7, 2)):
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + (i % 3) * 3
                                        ).astype(np.int32),
                    max_new=n)
            for i, n in enumerate(lens)]


def by_uid(done):
    return {r.uid: r.tokens for r in done}


# ------------------------------------------------------------ equivalence --

def test_paged_matches_contiguous_greedy():
    """Greedy decode through the paged path (chunked prefill + in-place
    decode) must be token-identical to the slot-contiguous engine — paging
    and chunking are a memory layout, not a model change.  Also proves the
    deprecated PagedServingEngine shim still answers like an engine."""
    cfg, params = build()
    out = {}
    for make in [
        lambda: ServingEngine(cfg, params, slots=2, max_len=64),
        lambda: PagedServingEngine(cfg, params, slots=2, page_size=8,
                                   num_pages=16),
    ]:
        eng = make()
        for r in mixed_requests(cfg, np.random.default_rng(7)):
            eng.submit(r)
        out[type(eng).__name__] = by_uid(eng.run())
    assert out["PagedServingEngine"] == out["ServingEngine"]


def test_paged_matches_contiguous_quantized_cache():
    """INT8 KV caches page too (values + per-row scales share page tables),
    chunked prefill included."""
    cfg, params = build(kv_quant=True)
    outs = []
    for make in [
        lambda: ServingEngine(cfg, params, slots=2, max_len=64),
        lambda: PagedServingEngine(cfg, params, slots=2, page_size=8,
                                   num_pages=16),
    ]:
        eng = make()
        for r in mixed_requests(cfg, np.random.default_rng(3), lens=(4, 6, 3)):
            eng.submit(r)
        outs.append(by_uid(eng.run()))
    assert outs[0] == outs[1]


def test_prompt_crossing_page_boundaries():
    """Prompts longer than one page (and one chunk) prefill into multiple
    pages correctly — the chunk stream writes pages in place as it goes."""
    cfg, params = build()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)  # 3 pages

    eng = ServingEngine(cfg, params, slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_new=6))
    want = eng.run()[0].tokens

    core = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=8,
                      chunk_size=8)
    core.submit(Request(uid=0, prompt=prompt.copy(), max_new=6))
    assert core.run()[0].tokens == want


# ---------------------------------------------------------- page lifecycle --

def test_pages_released_and_reused():
    """All pages return to the free list after a wave drains, and a second
    wave reusing those physical pages decodes identically."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=12,
                     chunk_size=8)

    def wave():
        for r in mixed_requests(cfg, np.random.default_rng(7)):
            eng.submit(r)
        done = by_uid(eng.run())
        eng.finished.clear()
        return done

    first = wave()
    assert eng.pages_in_use == 0
    assert sorted(eng.kv.free) == list(range(12))
    second = wave()                     # same traffic over recycled pages
    assert second == first
    assert eng.pages_in_use == 0


def test_pool_capped_traffic_drains():
    """A pool too small for all requests at once still drains — admission
    blocks on the budget, growth preempts-by-eviction — and no physical
    page is ever double-booked."""
    cfg, params = build()
    # each request peaks at ceil((7+8)/8) = 2 pages; a pool of 4 can hold
    # two grown requests — the other three wait or get evicted and resume
    eng = EngineCore(cfg, params, lanes=4, page_size=8, num_pages=4,
                     chunk_size=8)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(7, dtype=np.int32) + i,
                           max_new=8))
    while eng.scheduler.has_work():
        eng.step()
        live_pages = [p for t in eng.page_tables for p in t]
        assert len(live_pages) == len(set(live_pages)), "page double-booked"
        assert eng.pages_in_use <= 4
    assert len(eng.finished) == 5
    assert all(len(r.tokens) == 8 for r in eng.finished)
    assert eng.pages_in_use == 0


def test_lazy_page_growth():
    """Pages are allocated only as the token stream crosses page
    boundaries — a 6-token prompt starts on one page; the second page
    appears only once decode reaches row 8."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=8,
                     chunk_size=8)
    eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                       max_new=12))
    eng.step()
    assert len(eng.page_tables[0]) == 1          # 6-token prompt: one page
    for _ in range(4):
        eng.step()
    assert len(eng.page_tables[0]) == 2          # crossed row 8
    eng.run()
    assert eng.pages_in_use == 0


# ------------------------------------------------------- in-place serving --

def _jaxpr_shapes(jaxpr):
    """Every intermediate array shape in a jaxpr, nested subjaxprs included
    (pjit bodies, scan bodies, vmap — wherever the gather could hide)."""
    def sub(val):
        vals = val if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v

    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield tuple(aval.shape)
        for val in eqn.params.values():
            for j in sub(val):
                yield from _jaxpr_shapes(j)


def _step_jaxpr(eng, *, width, c, kv_len, q_len, npages):
    """Trace the engine's unified step at a given (chunk, table-width)."""
    tbl = np.full((eng.lanes, width), eng.kv.scratch, np.int32)
    tbl[0, :npages] = np.arange(npages, dtype=np.int32)
    return jax.make_jaxpr(eng._step)(
        eng.params, eng.kv.pool, jnp.asarray(tbl),
        jnp.zeros((eng.lanes, c), jnp.int32),
        jnp.asarray(kv_len, jnp.int32), jnp.asarray(q_len, jnp.int32))


@pytest.mark.parametrize("kv_quant", [False, True])
def test_decode_graph_has_no_gathered_view(kv_quant):
    """The paged decode step must never materialise the contiguous
    (B, …, width·page_size, …) cache view: every intermediate in the traced
    step graph is checked for the gathered-length dimension.  page_size=12
    with a 16-slot table makes that length 192 — longer than one attend
    block and a value no model/config dimension of the smoke config shares,
    so a hit can only be the gathered copy."""
    cfg, params = build(kv_quant=kv_quant)
    ps, width = 12, 16
    eng = EngineCore(cfg, params, lanes=2, page_size=ps, num_pages=32,
                     chunk_size=24)
    gathered_len = width * ps                              # 192

    jaxpr = _step_jaxpr(eng, width=width, c=1, kv_len=[151, 0],
                        q_len=[1, 0], npages=13)
    bad = [s for s in _jaxpr_shapes(jaxpr.jaxpr) if gathered_len in s]
    assert not bad, f"gathered cache view in decode graph: {bad}"

    # sanity: the detector does catch the legacy gather copy
    tbl = np.full((2, width), eng.kv.scratch, np.int32)
    legacy = jax.make_jaxpr(
        lambda pool: eng.kv.gather(pool, jnp.asarray(tbl)))(eng.kv.pool)
    assert any(gathered_len in s for s in _jaxpr_shapes(legacy.jaxpr))


@pytest.mark.parametrize("kv_quant", [False, True])
def test_chunked_prefill_graph_has_no_contiguous_cache(kv_quant):
    """Chunked prefill is in-place too: the traced chunk step contains no
    contiguous (B, n·page_size, …) KV intermediate — neither the padded
    table view (16·12 = 192) nor the old contiguous-prefill buffer that
    ``write_prefill`` used to scatter (13 pages · 12 = 156 rows for this
    prompt).  The contiguous-then-scatter path is structurally gone."""
    cfg, params = build(kv_quant=kv_quant)
    ps, width, chunk = 12, 16, 24
    eng = EngineCore(cfg, params, lanes=2, page_size=ps, num_pages=32,
                     chunk_size=chunk)
    # mid-prefill of a 150-token prompt: 120 rows resident, chunk 24 live
    jaxpr = _step_jaxpr(eng, width=width, c=chunk, kv_len=[120, 0],
                        q_len=[chunk, 0], npages=10)
    contiguous = {width * ps, 13 * ps, 150}
    bad = [s for s in _jaxpr_shapes(jaxpr.jaxpr)
           if contiguous.intersection(s)]
    assert not bad, f"contiguous KV intermediate in chunk graph: {bad}"
    # and write_prefill itself is gone from the pool API
    from repro.serving.paged import PagedKVCache
    assert not hasattr(PagedKVCache, "write_prefill")


# ------------------------------------------------------------- rejection --

@pytest.mark.parametrize("name,page_size,layout", [
    ("gemma2-9b-smoke", 16, "ring_buffer_sliding_window"),
    ("falcon-mamba-7b-smoke", 16, "ssm_state"),
])
def test_unpageable_layouts_rejected(name, page_size, layout):
    """Unpageable cache layouts raise a structured UnsupportedCacheLayout
    naming the offending layout (not a silent/shape-soup ValueError).
    gemma2 is only unpageable when page_size > window (a ring buffer would
    appear inside one page) — at page_size ≤ window its local layers keep
    full per-page caches and serve fine (see test_engine_core)."""
    cfg, params = build(name)
    with pytest.raises(UnsupportedCacheLayout, match="paged KV cache"
                       ) as ei:
        EngineCore(cfg, params, lanes=2, page_size=page_size, num_pages=8)
    assert ei.value.layout == layout
    assert layout in str(ei.value)
    # still a ValueError, so pre-redesign handlers keep working
    assert isinstance(ei.value, ValueError)
