"""HLO collective-byte accounting: synthetic text + a real lowered program."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (collective_totals, shape_bytes)
from tests._multidevice import run_with_devices

SYNTH = """
HloModule test

%body.1 (p: (f32[8], s32[])) -> (f32[8], s32[]) {
  %p = parameter(0)
  %x = f32[8]{0} get-tuple-element(%p), index=0
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups={{0,1,2,3}}, to_apply=%sum
  ROOT %t = tuple(%ar, %i)
}

ENTRY %main (a: f32[16], b: bf16[32]) -> f32[16] {
  %a = parameter(0)
  %b = parameter(1)
  %ag = f32[64]{0} all-gather(f32[16]{0} %a), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[32]{0} collective-permute(bf16[32]{0} %b), source_target_pairs={{0,1}}
  %w = (f32[8], s32[]) while((f32[8], s32[]) %init), condition=%cond.1, body=%body.1
  ROOT %r = f32[16]{0} reduce-scatter(f32[64]{0} %ag), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8]") == 32
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[]") == 1


def test_synthetic_module_totals():
    t = collective_totals(SYNTH, trip_hints=[10])
    assert t["op_all-gather"] == 64          # operand f32[16]
    assert t["op_collective-permute"] == 64  # bf16[32]
    assert t["op_reduce-scatter"] == 256     # operand f32[64]
    # the while body's all-reduce runs 10× (trip hint)
    assert t["op_all-reduce"] == 32 * 10
    assert t["total_operand_bytes"] == 64 + 64 + 256 + 320


def test_wire_model_factors():
    t = collective_totals(SYNTH, trip_hints=[1])
    # ring all-reduce: 2·(n-1)/n · bytes, n=4
    assert t["wire_all-reduce"] == 2 * 3 / 4 * 32
    # all-gather counts result bytes: (n-1)/n · 256
    assert t["wire_all-gather"] == 3 / 4 * 256


def test_real_lowered_psum_counted():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.launch.hlo_analysis import collective_totals

        mesh = make_mesh((4,), ("m",))
        f = shard_map(lambda x: jax.lax.psum(x, "m"),
                      mesh=mesh, in_specs=P("m"), out_specs=P())
        hlo = jax.jit(f).lower(jnp.zeros((64,), jnp.float32)).compile().as_text()
        t = collective_totals(hlo)
        assert t["op_all-reduce"] == 16 * 4, t   # 16 f32 per device
        print("OK")
    """, n_devices=4)
    assert "OK" in out
