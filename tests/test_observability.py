"""Unified serving observability: registry, spans, retrace sentinel.

The contracts under test (PR 10):

- **registry semantics** — counters/gauges/histograms with labeled
  families, snapshot/delta/ratio windows, count-offset histogram
  percentiles, kind conflicts rejected;
- **exporters** — Prometheus text exposition (cumulative buckets,
  ``_sum``/``_count``), JSON snapshot round-trip, and the asyncio
  ``/metrics`` endpoint serving both off an ephemeral port;
- **engine integration** — one served pass populates the registry with
  exactly the engine's own accounting (steps, tokens, traces, TTFT
  observations), the step ring records scheduler decisions, and a
  metrics-off engine emits identical tokens while writing nothing;
- **span lifecycle** — every request path (finish, abort mid-prefill,
  preempt-and-resume, speculative reject, server-side cancel) leaves one
  complete, ordered, *closed* span and no open-span leaks;
- **retrace sentinel** — after ``mark_warm()`` a warm engine serves
  fresh traffic with ``step_retraces_total == 0``, and the sentinel
  *fails* (counts retraces) if the scheduler's table-width high-water
  mark — the PR 8 shape-stability fix — is reverted.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.serving import (AsyncLMServer, EngineCore, Histogram,
                           MetricsRegistry, Request, RequestTracer,
                           Scheduler, StepTraceRing, start_metrics_server,
                           write_metrics_json)
from tests.test_engine_core import build, by_uid, prompts_for


# ------------------------------------------------------------- registry --

def test_counter_gauge_histogram_basics():
    r = MetricsRegistry()
    c = r.counter("reqs_total", "requests")
    c.inc()
    c.inc(4)
    assert c.value() == 5
    c.inc(2, packing="ragged")
    assert c.value(packing="ragged") == 2
    assert c.value() == 5                       # unlabeled series untouched

    g = r.gauge("pool_pages")
    g.set(7)
    g.set_max(3)                                # lower: no-op
    assert g.value() == 7
    g.set_max(11)
    assert g.value() == 11

    h = r.histogram("lat_ms")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count() == 4 and h.sum() == 10.0
    assert h.mean() == 2.5
    assert h.percentile(0.0) == 1.0
    assert h.percentile(1.0) == 4.0
    # count-offset window: skip the first two lifetime observations
    assert h.mean(skip=2) == 3.5
    assert h.percentile(0.0, skip=2) == 3.0

    assert r.value("reqs_total") == 5
    assert r.value("lat_ms") == 4               # histograms report count
    assert r.value("missing") == 0


def test_registry_get_or_create_and_kind_conflict():
    r = MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")
    assert r.names() == ["x"]


def test_snapshot_delta_ratio_windows():
    r = MetricsRegistry()
    hit, known = r.counter("hit"), r.counter("known")
    hit.inc(90)
    known.inc(100)
    snap = r.snapshot()
    hit.inc(5)
    known.inc(10)
    d = r.delta(snap)
    assert d["hit"] == 5 and d["known"] == 10
    assert r.ratio("hit", "known", since=snap) == 0.5
    assert r.ratio("hit", "known") == 95 / 110          # lifetime
    assert r.ratio("hit", "absent") == 0.0              # den 0 -> 0


def test_histogram_window_survives_reservoir_eviction():
    h = Histogram("h", max_samples=4)
    for v in range(10):                     # samples 0..5 fell off the deque
        h.observe(float(v))
    assert h.count() == 10
    # a skip older than the retained window degrades to "all retained"
    assert h.mean(skip=2) == np.mean([6.0, 7.0, 8.0, 9.0])
    assert h.mean(skip=8) == np.mean([8.0, 9.0])


# ------------------------------------------------------------ exporters --

def test_prometheus_text_exposition():
    r = MetricsRegistry()
    r.counter("a_total", "things").inc(3)
    r.gauge("b").set(1.5)
    h = r.histogram("lat_ms", "latency")
    h.observe(0.5)
    h.observe(30.0)
    text = r.prometheus_text()
    assert "# HELP a_total things" in text
    assert "# TYPE a_total counter" in text
    assert "a_total 3" in text
    assert "b 1.5" in text
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="1.0"} 1' in text          # cumulative
    assert 'lat_ms_bucket{le="50.0"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_sum 30.5" in text
    assert "lat_ms_count 2" in text


def test_json_snapshot_roundtrip(tmp_path):
    r = MetricsRegistry()
    r.counter("a_total").inc(3)
    r.histogram("lat_ms").observe(2.0)
    assert json.loads(r.json_text()) == json.loads(
        json.dumps(r.snapshot()))
    path = tmp_path / "metrics.json"
    write_metrics_json(r, str(path))
    got = json.loads(path.read_text())
    assert got["a_total"]["series"][""] == 3
    assert got["lat_ms"]["count"] == 1


def test_http_metrics_endpoint():
    r = MetricsRegistry()
    r.counter("scraped_total").inc(42)

    async def fetch(port, path):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write(f"GET {path} HTTP/1.0\r\n\r\n".encode())
        await writer.drain()
        data = await reader.read()
        writer.close()
        head, _, body = data.partition(b"\r\n\r\n")
        return head.decode(), body.decode()

    async def main():
        server = await start_metrics_server(r, port=0)
        port = server.sockets[0].getsockname()[1]
        try:
            prom = await fetch(port, "/metrics")
            js = await fetch(port, "/metrics.json")
            missing = await fetch(port, "/nope")
        finally:
            server.close()
            await server.wait_closed()
        return prom, js, missing

    prom, js, missing = asyncio.run(main())
    assert "200 OK" in prom[0] and "scraped_total 42" in prom[1]
    assert "200 OK" in js[0]
    assert json.loads(js[1])["scraped_total"]["series"][""] == 42
    assert "404" in missing[0]


# ---------------------------------------------------------------- spans --

def test_tracer_span_lifecycle():
    t = [0.0]
    tracer = RequestTracer(clock=lambda: t[0])
    tracer.begin(1, prompt_len=8)
    t[0] = 1.0
    tracer.event(1, "admitted")
    tracer.event(99, "admitted")               # unknown uid: no-op, no leak
    t[0] = 3.0
    span = tracer.end(1, "finished", generated=5)
    assert span.status == "finished" and not span.open
    assert span.event_names() == ["submitted", "admitted", "finished"]
    assert span.first("submitted").attrs == {"prompt_len": 8}
    assert span.duration_ms() == 3000.0
    assert tracer.open_spans() == {}
    assert tracer.span(1) is span              # closed spans stay findable

    # uid reuse while a span is still open orphans the stale one
    tracer.begin(2)
    tracer.begin(2)
    assert len([s for s in tracer.finished if s.status == "orphaned"]) == 1
    assert tracer.span(2).open


def test_step_trace_ring_is_bounded():
    ring = StepTraceRing(capacity=3)
    for i in range(5):
        ring.append({"step": i})
    assert len(ring) == 3
    assert [r["step"] for r in ring.records()] == [2, 3, 4]
    assert ring.last() == {"step": 4}


# ----------------------------------------------------- engine integration --

def _drain(eng):
    steps = 0
    while eng.scheduler.has_work():
        eng.step()
        steps += 1
        assert steps < 2000
    return steps


def test_engine_populates_registry_and_spans():
    """One served pass: every registry family reflects the engine's own
    accounting, each request leaves a complete closed span, and the step
    ring recorded every scheduling decision."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=3, page_size=8, num_pages=24,
                     chunk_size=8, mode="ragged")
    n = 4
    reqs = [Request(uid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts_for(cfg, 3, (3, 9, 14, 6)))]
    for r in reqs:
        eng.submit(r)
    steps = _drain(eng)

    reg = eng.obs.registry
    assert reg.value("steps_total") == steps
    assert reg.value("requests_submitted_total") == n
    assert reg.value("requests_admitted_total") == n
    assert reg.value("requests_finished_total") == n
    assert reg.value("tokens_generated_total") == sum(
        len(r.tokens) for r in reqs)
    assert reg.value("step_traces_total") == eng.trace_count
    assert reg.value("step_retraces_total") == 0       # never marked warm
    assert eng.obs.h_ttft_ms.count() == n              # one TTFT each
    assert eng.obs.h_step_ms.count() == steps
    assert reg.value("pool_pages_in_use") == 0         # drained
    assert reg.value("pool_pages_in_use_peak") > 0

    assert eng.obs.tracer.open_spans() == {}           # no leaks
    for r in reqs:
        span = eng.obs.tracer.span(r.uid)
        assert span.status == "finished"
        names = span.event_names()
        assert names[0] == "submitted" and names[-1] == "finished"
        assert names.index("admitted") < names.index("first_token")

    assert len(eng.obs.ring) == steps
    rec = eng.obs.ring.last()
    for key in ("width", "table_pages", "live_rows", "padded_rows",
                "prefill_tokens", "decode_tokens", "pool_pages_in_use",
                "dur_ms"):
        assert key in rec


def test_metrics_off_engine_is_inert_and_token_identical():
    cfg, params = build()
    kw = dict(lanes=3, page_size=8, num_pages=24, chunk_size=8,
              mode="ragged")
    def reqs():
        return [Request(uid=i, prompt=p, max_new=5) for i, p in
                enumerate(prompts_for(cfg, 3, (3, 9, 14, 6)))]

    on = EngineCore(cfg, params, **kw)
    off = EngineCore(cfg, params, metrics=False, **kw)
    ra, rb = reqs(), reqs()
    for a, b in zip(ra, rb):
        on.submit(a)
        off.submit(b)
    _drain(on)
    _drain(off)
    assert by_uid(ra) == by_uid(rb)
    assert not off.obs.enabled
    assert off.obs.registry.value("steps_total") == 0
    assert len(off.obs.ring) == 0
    assert off.obs.tracer.open_spans() == {}
    assert on.obs.registry.value("steps_total") > 0


# -------------------------------------------------------- span lifecycle --

def test_abort_mid_prefill_closes_span():
    """Aborting a request whose prompt is still streaming chunks ends its
    span as 'aborted' with no first_token and leaks nothing."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=32,
                     chunk_size=4, mode="ragged")
    prompt = prompts_for(cfg, 5, (24,))[0]     # 6 chunks of 4
    eng.submit(Request(uid=0, prompt=prompt, max_new=8))
    eng.step()                                 # first prefill chunk only
    assert eng.abort(0)
    span = eng.obs.tracer.span(0)
    assert span.status == "aborted"
    assert span.event_names() == ["submitted", "admitted", "aborted"]
    assert eng.obs.tracer.open_spans() == {}
    assert eng.obs.registry.value("requests_aborted_total") == 1
    assert eng.obs.registry.value("requests_finished_total") == 0
    assert eng.pages_in_use == 0


def test_preempt_and_resume_events_in_span():
    """Pool contention: the evicted request's span records preempted then
    resumed, and still closes as finished."""
    cfg, params = build()
    specs = [(4, 26), (12, 14)]                # contended at 8 pages
    prompts = prompts_for(cfg, 21, [lp for lp, _ in specs])
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=8,
                     chunk_size=4, mode="ragged")
    for uid, (lp, mn) in enumerate(specs):
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
    _drain(eng)
    assert eng.obs.registry.value("preemptions_total") >= 1
    assert eng.obs.registry.value("requests_resumed_total") >= 1
    preempted = [uid for uid in (0, 1)
                 if "preempted" in eng.obs.tracer.span(uid).event_names()]
    assert preempted, "pool contention never evicted anyone"
    for uid in preempted:
        span = eng.obs.tracer.span(uid)
        names = span.event_names()
        assert span.status == "finished"
        assert names.index("preempted") < names.index("resumed")
    assert eng.obs.tracer.open_spans() == {}


def test_speculative_rejection_recorded_in_span():
    """An always-wrong proposer: every drafted token is verified and
    rejected — spans carry spec_verify events with accepted == 0 and the
    registry's acceptance window is 0."""
    cfg, params = build()

    def off_by_one(stream, k):                 # wrong draft every time
        return [int(stream[-1] + 1) % cfg.vocab_size] * k

    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=24,
                     chunk_size=8, mode="ragged", speculative=True,
                     spec_k=3, proposer=off_by_one)
    for i, p in enumerate(prompts_for(cfg, 11, (6, 9))):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    _drain(eng)
    reg = eng.obs.registry
    assert reg.value("spec_drafted_tokens_total") > 0
    assert reg.value("spec_accepted_tokens_total") == 0
    verifies = [e for uid in (0, 1)
                for e in eng.obs.tracer.span(uid).events
                if e.name == "spec_verify"]
    assert verifies
    assert all(e.attrs["accepted"] == 0 for e in verifies)
    assert all(e.attrs["drafted"] > 0 for e in verifies)


def test_server_cancel_closes_span_and_counts_stream():
    """A client breaking out of its stream aborts the request: the span
    closes as 'aborted', the stream-cancel counter bumps, and survivors'
    spans finish normally."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=32,
                     chunk_size=8, mode="ragged")
    reqs = [Request(uid=i, prompt=p, max_new=8)
            for i, p in enumerate(prompts_for(cfg, 9, (5, 7)))]

    async def consume(server, req, cancel_after=None):
        toks = []
        async for tok in server.generate(req):
            toks.append(tok)
            if cancel_after is not None and len(toks) >= cancel_after:
                break
        return toks

    async def main():
        async with AsyncLMServer(eng) as server:
            return await asyncio.gather(
                consume(server, reqs[0], cancel_after=2),
                consume(server, reqs[1]))

    outs = asyncio.run(main())
    assert len(outs[0]) == 2 and len(outs[1]) == 8
    reg = eng.obs.registry
    assert reg.value("stream_cancelled_total") == 1
    assert reg.value("stream_requests_total") == 1      # finished streams
    assert reg.value("requests_aborted_total") == 1
    assert eng.obs.tracer.span(0).status == "aborted"
    assert eng.obs.tracer.span(1).status == "finished"
    assert eng.obs.tracer.open_spans() == {}


# ------------------------------------------------------ retrace sentinel --

_BUCKETS = (1, 2, 4, 8, 16)        # pow2-only: solo(3) and 3+1 both -> 4


def _sentinel_engine(cfg, params):
    return EngineCore(cfg, params, lanes=2, page_size=4, num_pages=24,
                      chunk_size=8, max_len=64, mode="ragged",
                      token_buckets=_BUCKETS)


def _sentinel_warm_pass(eng, cfg, uid0):
    """One warm-up pass: a long request grows its page table past the
    16-page bucket, then two short requests co-batch with its decode (so
    their shapes are traced AT the high-water table width), and the long
    drains last (covering the solo widths at that width too)."""
    long_p, = prompts_for(cfg, 17, (16,))
    eng.submit(Request(uid=uid0, prompt=long_p, max_new=40))
    for _ in range(20):            # 2 prefill chunks + 18 decodes: the
        if not eng.scheduler.has_work():       # table crosses 8 pages
            break
        eng.step()
    for j, p in enumerate(prompts_for(cfg, 29 + uid0, (3, 3))):
        eng.submit(Request(uid=uid0 + 1 + j, prompt=p, max_new=3))
    _drain(eng)                    # shorts finish first; long drains solo
    eng.finished.clear()


def _sentinel_probe(eng, cfg, uid):
    """Post-warm traffic: one short request served solo — the shape the
    table-width HWM keeps stable (and its absence destabilizes)."""
    p, = prompts_for(cfg, 43, (3,))
    eng.submit(Request(uid=uid, prompt=p, max_new=3))
    _drain(eng)
    return int(eng.obs.registry.value("step_retraces_total"))


def test_warm_engine_serves_fresh_traffic_with_zero_retraces():
    """The zero-retrace regression gate: warm-up passes repeat until one
    compiles nothing new, then mark_warm() arms the sentinel and fresh
    solo traffic must hit only cached shapes — the table-width high-water
    mark (PR 8) guarantees the table's P axis never shrinks under it."""
    cfg, params = build()
    eng = _sentinel_engine(cfg, params)
    for i in range(6):
        t0 = eng.trace_count
        _sentinel_warm_pass(eng, cfg, uid0=10 * i)
        if eng.trace_count == t0:
            break
    assert eng.trace_count == t0, "warm-up never became trace-stable"
    assert eng.obs.registry.value(
        "step_traces_total") == eng.trace_count

    eng.obs.mark_warm()
    assert _sentinel_probe(eng, cfg, uid=900) == 0


def test_sentinel_catches_table_width_hwm_revert(monkeypatch):
    """The discriminating half of the gate: revert the PR 8 high-water
    mark (let the table width shrink to fit the resident mix) and the
    SAME warm-up + probe shows retraces > 0 — a solo short request packs
    at a narrow table width no warm-up shape ever used.  Proves the gate
    fails if the shape-stability fix regresses, rather than passing
    vacuously."""
    orig = Scheduler.pack

    def pack_without_hwm(self, plans):
        self._table_pages = 1          # the revert: no high-water mark
        return orig(self, plans)

    monkeypatch.setattr(Scheduler, "pack", pack_without_hwm)
    cfg, params = build()
    eng = _sentinel_engine(cfg, params)
    for i in range(6):
        t0 = eng.trace_count
        _sentinel_warm_pass(eng, cfg, uid0=10 * i)
        if eng.trace_count == t0:
            break
    eng.obs.mark_warm()
    assert _sentinel_probe(eng, cfg, uid=900) > 0
