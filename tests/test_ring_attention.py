"""Ring attention + distributed decode vs the single-device oracle."""
from tests._multidevice import run_with_devices


def test_ring_attention_matches_naive():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.core.ring_attention import ring_attention
        from repro.core.streaming_attention import naive_attention

        mesh = make_mesh((4,), ("sp",))
        rng = np.random.default_rng(0)
        B, Hq, Hkv, L, D = 2, 4, 2, 64, 16
        q = jnp.asarray(rng.normal(size=(B, Hq, L, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))

        for kw in (dict(causal=True), dict(causal=True, window=24),
                   dict(causal=False, cap=25.0)):
            f = shard_map(
                functools.partial(ring_attention, axis_name="sp", **kw),
                mesh=mesh,
                in_specs=(P(None, None, "sp"), P(None, None, "sp"),
                          P(None, None, "sp")),
                out_specs=P(None, None, "sp"))
            got = np.asarray(f(q, k, v))
            want = np.asarray(naive_attention(q, k, v, exp_mode="lut", **kw))
            np.testing.assert_allclose(got, want, atol=5e-5, rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_distributed_decode_matches_naive():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.core.ring_attention import distributed_decode_attention
        from repro.core.streaming_attention import naive_attention

        mesh = make_mesh((8,), ("sp",))
        rng = np.random.default_rng(1)
        B, Hq, Hkv, L, D = 2, 4, 4, 128, 16
        kv_len = 100
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))

        f = shard_map(
            functools.partial(distributed_decode_attention, axis_name="sp",
                              kv_len=jnp.int32(kv_len)),
            mesh=mesh,
            in_specs=(P(), P(None, None, "sp"), P(None, None, "sp")),
            out_specs=P())
        got = np.asarray(f(q, k, v))
        want = np.asarray(naive_attention(
            q, k, v, causal=True, q_offset=kv_len - 1, kv_len=kv_len,
            exp_mode="lut"))
        np.testing.assert_allclose(got, want, atol=5e-5, rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out
