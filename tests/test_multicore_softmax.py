"""Multi-core softmax (paper §III-B2): sharded == full, tree == collective."""
from tests._multidevice import run_with_devices


def test_sharded_softmax_matches_full():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.core.multicore_softmax import (sharded_softmax,
                                                  sharded_softmax_tree)
        from repro.core.lut_softmax import lut_softmax

        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32) * 5)

        f = shard_map(
            functools.partial(sharded_softmax, axis_name="model"),
            mesh=mesh, in_specs=P(None, "model"), out_specs=P(None, "model"))
        got = np.asarray(f(x))
        want = np.asarray(lut_softmax(x))
        np.testing.assert_allclose(got, want, atol=3e-6)

        g = shard_map(
            functools.partial(sharded_softmax_tree, axis_name="model"),
            mesh=mesh, in_specs=P(None, "model"), out_specs=P(None, "model"))
        got_tree = np.asarray(g(x))
        # the explicit ppermute butterfly is step-for-step equivalent
        np.testing.assert_allclose(got_tree, got, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_tree_allreduce_is_logn():
    """The butterfly must use exactly log2(n) ppermute rounds."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.core.multicore_softmax import tree_allreduce

        mesh = make_mesh((8,), ("m",))
        f = shard_map(
            lambda x: tree_allreduce(x, jnp.add, "m"),
            mesh=mesh, in_specs=P("m"), out_specs=P("m"))
        x = jnp.arange(8.0)
        assert float(f(x)[0]) == 28.0          # Σ 0..7 on every shard
        hlo = jax.jit(f).lower(x).as_text()
        n_permutes = hlo.count("collective_permute")
        assert n_permutes >= 3, n_permutes      # log2(8) rounds
        print("OK", n_permutes)
    """)
    assert "OK" in out
