"""Deterministic fallback for the tiny slice of `hypothesis` this suite uses.

The CI image does not always ship `hypothesis`; rather than skip the property
tests wholesale, this module re-implements the used surface — ``given``,
``settings``, ``strategies.floats/integers/sampled_from`` and
``hypothesis.extra.numpy.arrays`` — as a seeded example sampler.  Real
hypothesis, when installed, is always preferred (see the try/except import in
each test module); this stub trades shrinking/coverage smarts for zero deps
while keeping every property exercised on a few dozen deterministic examples,
including interval endpoints.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, List

import numpy as np

_MAX_EXAMPLES_CAP = 50  # CPU-CI budget; hypothesis proper runs the full count


class _Strategy:
    def __init__(self, sample: Callable[[np.random.Generator], Any],
                 endpoints: List[Any] = ()):  # noqa: B006 - immutable default
        self._sample = sample
        self.endpoints = list(endpoints)

    def example(self, rng: np.random.Generator) -> Any:
        return self._sample(rng)


def floats(min_value: float = -1e9, max_value: float = 1e9, *,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64, **_: Any) -> _Strategy:
    lo, hi = float(min_value), float(max_value)

    def sample(rng):
        x = float(rng.uniform(lo, hi))
        return float(np.float32(x)) if width == 32 else x

    return _Strategy(sample, endpoints=[lo, hi, 0.0] if lo <= 0.0 <= hi
                     else [lo, hi])


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                     endpoints=[min_value, max_value])


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(len(items)))],
                     endpoints=items[:2])


class st:  # mirrors `hypothesis.strategies`
    floats = staticmethod(floats)
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)


class hnp:  # mirrors `hypothesis.extra.numpy`
    @staticmethod
    def arrays(dtype, shape, *, elements: _Strategy, **_: Any) -> _Strategy:
        shape = tuple(shape) if not isinstance(shape, int) else (shape,)

        def sample(rng):
            flat = [elements.example(rng) for _ in range(int(np.prod(shape)))]
            return np.asarray(flat, dtype=dtype).reshape(shape)

        return _Strategy(sample)


def settings(max_examples: int = 20, deadline=None, **_: Any):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    """Run the wrapped test on endpoint examples + seeded random samples."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", 20), _MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(0)
            cases = []
            if all(s.endpoints for s in strategies):
                width = max(len(s.endpoints) for s in strategies)
                for i in range(width):
                    cases.append(tuple(s.endpoints[i % len(s.endpoints)]
                                       for s in strategies))
            while len(cases) < n:
                cases.append(tuple(s.example(rng) for s in strategies))
            for vals in cases[:n]:
                fn(*args, *vals, **kwargs)
        # All params are strategy-bound: hide them from pytest's fixture
        # resolution (real hypothesis does the same).
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco
