"""Validation of the analytical CIM model against the paper's claims.

Calibrated anchors (exact by construction; asserted tight):
  Fig 7 @ l=8192, W=16 — PUMA 22.13 µs, UCLM 6 µs, multicore 1.36 µs
  Fig 12 — BERT-Base 158 TOPS

Everything else is PREDICTED from those constants and checked against the
paper at the stated tolerance.  Loose tolerances are model limitations
documented in DESIGN.md (our PUMA intra-layer parallelism model is
conservative)."""
import math

import pytest

from repro.perfmodel import (BERT_BASE, BERT_LARGE, DEFAULT_HW, GPU,
                             encoder_layer_latency_s, end_to_end_tops,
                             headline_numbers, softmax_cores,
                             softmax_energy_j, softmax_fraction,
                             softmax_latency_s, tops_per_watt)

HW = DEFAULT_HW
H = headline_numbers()


def close(got, want, tol):
    assert abs(got / want - 1) <= tol, f"got {got:.4g}, want {want:.4g}"


# ------------------------------------------------ anchors (calibration) --

def test_fig7_softmax_anchor_puma():
    close(H["softmax_puma_8192_w16_us"], 22.13, 0.01)


def test_fig7_softmax_anchor_uclm():
    close(H["softmax_uclm_8192_w16_us"], 6.0, 0.01)


def test_fig7_softmax_anchor_multicore():
    close(H["softmax_multicore_8192_w16_us"], 1.36, 0.05)


def test_fig12_tops_anchor_bert_base():
    close(H["tops_bert_base"], 158.0, 0.02)


# ------------------------------------------------------- predictions --

def test_fig7_alu_width_gain():
    """Paper: W 16→64 improves multicore softmax by 22% at l=8192."""
    close(H["softmax_w64_gain_pct"], 22.0, 0.15)


def test_fig7_multicore_only_helps_when_long():
    """Paper: 'no difference at smaller l' — hastily == uclm for l ≤ 1024."""
    for l in (128, 512, 1024):
        h = softmax_latency_s(HW, l, "hastily")
        u = softmax_latency_s(HW, l, "uclm")
        assert h <= u and (u - h) / u < 0.35
    # and a big win at 8192
    assert (softmax_latency_s(HW, 8192, "uclm", 16)
            / softmax_latency_s(HW, 8192, "multicore", 16)) > 3


def test_fig8_energy_ratio():
    """Paper: PUMA ≈ 1.6× HASTILY softmax energy for l > 1024."""
    for l in (2048, 4096, 8192):
        r = (softmax_energy_j(HW, l, "puma")
             / softmax_energy_j(HW, l, "multicore"))
        close(r, 1.6, 0.15)


def test_fig8_multicore_energy_overhead_small():
    """Paper: 'small energy difference between UCLM only and multi-core'."""
    for l in (2048, 8192):
        r = (softmax_energy_j(HW, l, "multicore")
             / softmax_energy_j(HW, l, "uclm"))
        assert 1.0 <= r < 1.15


def test_fig10_softmax_runtime_share():
    """Paper: softmax is 38% of PUMA's un-pipelined layer at l=1024,
    reduced to 13% with UCLM+multicore (we predict 16%)."""
    close(softmax_fraction(HW, 1024, 768, "puma"), 0.38, 0.10)
    assert softmax_fraction(HW, 1024, 768, "hastily") < 0.20


def test_fig9_combined_speedup():
    """Paper: at emb 768, l=1024 — softmax accel + pipelining ≈ 4.47× over
    PUMA (softmax alone 37%, pipelining alone 96%)."""
    puma = encoder_layer_latency_s(HW, 1024, 768, softmax_mode="puma",
                                   pipelined="none")
    sm_only = encoder_layer_latency_s(HW, 1024, 768, softmax_mode="hastily",
                                      pipelined="none")
    pipe_only = encoder_layer_latency_s(HW, 1024, 768, softmax_mode="puma",
                                        pipelined="coarse")
    both = encoder_layer_latency_s(HW, 1024, 768, softmax_mode="hastily",
                                   pipelined="fine")
    assert puma / both == pytest.approx(4.47, rel=0.25)
    assert 1.2 < puma / sm_only < 2.0          # softmax accel alone
    assert 1.5 < puma / pipe_only < 3.0        # pipelining alone


def test_fig12_bert_large():
    close(H["tops_bert_large"], 263.0, 0.10)


def test_fig12_batch4_equals_batch2():
    """Paper: 'batch 4 ... performance identical to batch size 2'."""
    t2 = end_to_end_tops(HW, 12, 512, 768, 3072, batch=2)
    t4 = end_to_end_tops(HW, 12, 512, 768, 3072, batch=4)
    close(t4, t2, 0.01)


def test_fig12_speedup_vs_gpu_in_range():
    """Paper: 4.4–9.8× TOPS over the A40."""
    assert 4.4 <= H["speedup_tops_vs_gpu_base"] <= 9.8


def test_fig12_speedup_vs_puma_in_range():
    """Paper: 1.7–5.9× over baseline CIM (PUMA).  Our PUMA model is
    conservative, so check against the paper's own PUMA figure (26 TOPS)."""
    assert 1.7 <= H["tops_bert_base"] / 26.0 <= 9.0
    # and our modelled PUMA lands within 25% of the paper's 26 TOPS
    close(H["tops_puma_bert_base"], 26.0, 0.25)


def test_fig13_tops_per_watt():
    """Paper: HASTILY ≈ 8 TOPS/W regardless of model size."""
    base = tops_per_watt(HW, 12, 512, 768, 3072, batch=2)
    large = tops_per_watt(HW, 24, 512, 1024, 4096, batch=2)
    close(base, 8.0, 0.10)
    close(large, 8.0, 0.15)


def test_fig13_energy_efficiency_vs_gpu():
    """Paper: 16–36× TOPS/W over the A40."""
    assert 16 <= H["tops_w_vs_gpu_b1"] <= 36


def test_softmax_cores_mapping():
    assert softmax_cores(HW, 256) == 1
    assert softmax_cores(HW, 8192) == 16
    assert softmax_cores(HW, 10 ** 6) == 16      # capped


def test_pipeline_latency_is_n_plus_1():
    """Paper §IV: N-layer encoder in (N+1)·seqLen MVM-times."""
    from repro.perfmodel import end_to_end_latency_s, BERT_BASE
    t = end_to_end_latency_s(HW, 12, 512, 768, 3072, batch=1)
    assert t == pytest.approx(13 * 512 * HW.t_mvm_ns * 1e-9, rel=1e-6)
