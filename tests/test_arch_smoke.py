"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SHAPES, all_cells, cell_status, get_config
from repro.models import batch_specs, build_model

ALL = list(ASSIGNED) + ["bert-base", "bert-large"]


def _batch(cfg, b, l, rng):
    out = {}
    for k, v in batch_specs(cfg, b, l).items():
        if v.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, v.shape),
                                 jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape).astype(np.float32)
                                 * 0.05, v.dtype)
    return out


@pytest.mark.parametrize("name", ALL)
def test_train_step(name, rng):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 16, rng)
    loss, aux = model.loss(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), name
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, name


@pytest.mark.parametrize("name", ALL)
def test_prefill_decode_shapes(name, rng):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, L = 2, 12
    caches = model.init_cache(B, L + 4)
    batch = {k: v for k, v in _batch(cfg, B, L, rng).items() if k != "labels"}
    logits, state = model.prefill(params, batch, caches)
    if model.decode_step is None:        # encoder-only (bert): full-seq MLM
        assert cfg.family == "bert"
        assert logits.shape == (B, L, cfg.vocab_size), (name, logits.shape)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name
        return
    assert logits.shape == (B, cfg.vocab_size), (name, logits.shape)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name
    tok = jnp.zeros((B,), jnp.int32)
    lg, state = model.decode_step(params, tok, state, jnp.int32(L))
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all()), name


def test_full_configs_match_assignment():
    """The registry must carry the exact published dimensions."""
    expect = {
        "seamless-m4t-large-v2": dict(d_model=1024, num_heads=16,
                                      num_kv_heads=16, d_ff=8192,
                                      vocab_size=256206),
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536,
                                     num_heads=24, num_kv_heads=8, d_ff=512,
                                     vocab_size=49155, num_experts=40,
                                     experts_per_token=8),
        "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                            num_kv_heads=8, d_ff=32768, vocab_size=131072,
                            num_experts=8, experts_per_token=2),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096,
                                vocab_size=65024, ssm_state=16),
        "internvl2-1b": dict(num_layers=24, d_model=896, num_heads=14,
                             num_kv_heads=2, d_ff=4864, vocab_size=151655),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
        "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                              num_kv_heads=2, d_ff=12288, vocab_size=49152),
        "gemma2-9b": dict(num_layers=42, d_model=3584, num_heads=16,
                          num_kv_heads=8, d_ff=14336, vocab_size=256000),
        "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008, vocab_size=102400),
        "gemma3-12b": dict(num_layers=48, d_model=3840, num_heads=16,
                           num_kv_heads=8, d_ff=15360, vocab_size=262144),
    }
    for name, fields in expect.items():
        cfg = get_config(name)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_cell_matrix():
    cells = all_cells()
    assert len(cells) == 40
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2].startswith("skip")]
    assert len(skips) == 6      # long_500k for the 6 full-attention archs
    for a, s, st in skips:
        assert s == "long_500k"
    # sub-quadratic archs DO run long_500k
    assert ("falcon-mamba-7b", "long_500k", "run") in cells
    assert ("gemma3-12b", "long_500k", "run") in cells


def test_param_counts_plausible():
    """Analytic parameter counts should land near the advertised sizes."""
    approx = {"grok-1-314b": 314e9, "falcon-mamba-7b": 7e9,
              "deepseek-7b": 7e9, "gemma2-9b": 9e9, "gemma3-12b": 12e9,
              "starcoder2-3b": 3e9}
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 1.8 * target, (name, n / 1e9)
