"""LUT softmax vs exact softmax; stability and masking invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st, hnp

from repro.core.lut_softmax import lut_log_softmax, lut_softmax, softcap


def test_matches_exact_softmax(rng):
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32) * 5)
    got = np.asarray(lut_softmax(x))
    want = np.asarray(jax.nn.softmax(x, axis=-1))
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_rows_sum_to_one(rng):
    x = jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32) * 30)
    s = np.asarray(lut_softmax(x)).sum(-1)
    np.testing.assert_allclose(s, 1.0, atol=1e-5)


def test_overflow_stability():
    """Paper Eq. 1: the max subtraction must keep huge logits finite."""
    x = jnp.array([[1e4, 1e4 - 1.0, 0.0]])
    p = np.asarray(lut_softmax(x))
    assert np.isfinite(p).all() and p[0, 2] == 0.0
    assert p[0, 0] > p[0, 1] > 0


def test_masking():
    x = jnp.zeros((1, 4))
    mask = jnp.array([[True, True, False, False]])
    p = np.asarray(lut_softmax(x, where=mask))
    np.testing.assert_allclose(p[0], [0.5, 0.5, 0.0, 0.0], atol=1e-6)


def test_all_masked_row_is_zero():
    p = np.asarray(lut_softmax(jnp.zeros((1, 4)),
                               where=jnp.zeros((1, 4), bool)))
    np.testing.assert_array_equal(p, 0.0)


def test_log_softmax_consistent(rng):
    x = jnp.asarray(rng.normal(size=(5, 17)).astype(np.float32) * 3)
    lp = np.asarray(lut_log_softmax(x))
    np.testing.assert_allclose(np.exp(lp), np.asarray(lut_softmax(x)),
                               atol=5e-5)


def test_softcap():
    x = jnp.array([-1e4, 0.0, 1e4])
    y = np.asarray(softcap(x, 30.0))
    assert abs(y[0] + 30) < 1e-3 and y[1] == 0 and abs(y[2] - 30) < 1e-3
    np.testing.assert_array_equal(np.asarray(softcap(x, None)), np.asarray(x))


@given(hnp.arrays(np.float32, (3, 16),
                  elements=st.floats(-50, 50, width=32)))
@settings(max_examples=100, deadline=None)
def test_shift_invariance(x):
    """Property: softmax(x + c) == softmax(x) — the stable-form guarantee."""
    p1 = np.asarray(lut_softmax(jnp.asarray(x)))
    p2 = np.asarray(lut_softmax(jnp.asarray(x) + 13.7))
    np.testing.assert_allclose(p1, p2, atol=2e-4)
