"""EngineCore + Scheduler: the request-level serving API.

Covers the redesign's contracts: mixed chunked-prefill + decode batches are
token-identical to the PR-2 engines (float and int8); a stream of distinct
prompt lengths compiles O(1) step functions (chunking makes shapes static);
preemption-by-eviction resumes token-identically; chunked paged prefill
matches the contiguous prefill oracle over ragged lengths, chunk sizes
{1, ps, 3·ps}, GQA and int8 pools; token-budget fairness keeps decode lanes
ahead of prefill bursts; sliding-window configs page when page_size ≤
window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EngineCore, Request, RequestState, ServingEngine,
                           StepOutput)


def build(name="deepseek-7b-smoke", **replace):
    cfg = get_config(name)
    if replace:
        cfg = cfg.replace(**replace)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def prompts_for(cfg, seed, lens):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, lp).astype(np.int32)
            for lp in lens]


def by_uid(done):
    return {r.uid: r.tokens for r in done}


# --------------------------------------------------- mixed-batch identity --

@pytest.mark.parametrize("kv_quant", [False, True])
def test_step_token_identical_to_pr2_engines(kv_quant):
    """EngineCore.step() with mixed chunked-prefill + decode lanes emits the
    same greedy token streams as the slot-contiguous engine on the same
    request trace (lowest-index tie-break), float and int8.  Prompt lengths
    straddle chunk and page boundaries so early requests are decoding while
    later ones still stream prefill chunks — the mixed batch is exercised,
    not just reachable."""
    cfg, params = build(kv_quant=kv_quant)
    lens = (3, 21, 9, 14, 6)
    news = (7, 5, 9, 4, 6)

    def submit_all(eng):
        for i, p in enumerate(prompts_for(cfg, 13, lens)):
            eng.submit(Request(uid=i, prompt=p, max_new=news[i]))

    slot = ServingEngine(cfg, params, slots=3, max_len=64)
    submit_all(slot)
    want = by_uid(slot.run())

    core = EngineCore(cfg, params, lanes=3, page_size=8, num_pages=24,
                      chunk_size=8)
    submit_all(core)
    outs = []
    while core.scheduler.has_work():
        outs.append(core.step())
    assert by_uid(core.finished) == want
    assert any(o.mixed for o in outs), "no step mixed prefill with decode"


# ------------------------------------------------------- compile counting --

def test_distinct_prompt_lengths_compile_O1_step_functions():
    """The recompile fallout of the per-prompt-length b=1 prefill is gone:
    chunking makes every step shape static, so step functions are keyed
    only by (chunk width ∈ {1, C}) × (power-of-two table width) — never by
    prompt length.  Lengths 3/12/21 deterministically cover all six combos
    for this pool; a second stream of seven *new* distinct lengths then
    traces nothing at all (the PR-2 engines compiled one prefill per
    length)."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=64,
                     chunk_size=8)

    def serve(lens, seed):
        for i, p in enumerate(prompts_for(cfg, seed, lens)):
            eng.submit(Request(uid=seed * 100 + i, prompt=p, max_new=2))
        eng.run()
        eng.finished.clear()

    serve((3, 12, 21), seed=1)
    traced = eng.trace_count
    assert traced <= 6          # widths {1, C} × table buckets {1, 2, 4}
    serve((4, 7, 11, 13, 17, 19, 20), seed=2)   # 7 new distinct lengths
    assert eng.trace_count == traced, (
        f"new prompt lengths retraced the step: {traced} → "
        f"{eng.trace_count}")


# ------------------------------------------------------------ preemption --

def test_preempted_request_resumes_token_identical():
    """Fill the pool with a long-running request, admit a longer prompt;
    the pool exhausts mid-flight, the youngest resident is evicted
    (recompute preemption) and later resumes — and every request's token
    stream is identical to an uncontended (solo, full-pool) run."""
    cfg, params = build()
    specs = [(4, 26), (12, 14)]            # (prompt_len, max_new)
    prompts = prompts_for(cfg, 21, [lp for lp, _ in specs])

    solo = {}
    for uid, (lp, mn) in enumerate(specs):
        eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=16,
                         chunk_size=4)
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
        solo[uid] = eng.run()[0].tokens

    # contended: 8 pages cannot hold both peaks (8 + 7 pages)
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=8,
                     chunk_size=4)
    preempted_seen = []
    for uid, (lp, mn) in enumerate(specs):
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
    while eng.scheduler.has_work():
        out = eng.step()
        preempted_seen.extend(out.preempted)
    assert preempted_seen, "pool contention never triggered an eviction"
    got = by_uid(eng.finished)
    assert got == solo, "preempted request did not resume token-identically"
    assert eng.pages_in_use == 0
    # the evicted request went through the PREEMPTED state and finished
    evicted = eng.finished[-1] if eng.finished[-1].uid in preempted_seen \
        else eng.finished[0]
    assert evicted.state is RequestState.FINISHED


def test_oldest_resident_is_never_evicted():
    """Eviction picks strictly younger residents, so the oldest request
    always runs to completion — the progress guarantee behind
    preemption-by-eviction."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=3, page_size=4, num_pages=8,
                     chunk_size=4)
    for i, p in enumerate(prompts_for(cfg, 3, (6, 6, 6))):
        eng.submit(Request(uid=i, prompt=p, max_new=20))
    first_done = None
    while eng.scheduler.has_work():
        out = eng.step()
        assert 0 not in out.preempted, "oldest request was evicted"
        if first_done is None and out.finished:
            first_done = out.finished[0]
    assert first_done == 0      # FCFS: the oldest finishes first here


# ------------------------------------------- chunked-prefill equivalence --

def _drive_chunked_prefill(model, params, core, prompts, chunk):
    """Manually stream ragged prompts through the unified chunk step (the
    exact EngineCore dataflow) and return each lane's final-row logits."""
    kv = core.kv
    lanes = len(prompts)
    pages = [[] for _ in prompts]
    rows = [0] * lanes
    final = [None] * lanes
    while any(rows[i] < len(prompts[i]) for i in range(lanes)):
        q_len = np.zeros((lanes,), np.int32)
        kv_len = np.zeros((lanes,), np.int32)
        toks = np.zeros((lanes, chunk), np.int32)
        for i, p in enumerate(prompts):
            c = min(chunk, len(p) - rows[i])
            if c <= 0:
                continue
            while len(pages[i]) < kv.pages_needed(rows[i] + c):
                pages[i].append(kv.alloc())
            toks[i, chunk - c:] = p[rows[i]:rows[i] + c]
            q_len[i] = c
            kv_len[i] = rows[i] + c
            rows[i] += c
        width = 1 << max(max(len(pg) for pg in pages) - 1, 0).bit_length()
        tbl = np.full((lanes, width), kv.scratch, np.int32)
        for i, pg in enumerate(pages):
            tbl[i, :len(pg)] = pg
        logits, kv.pool = core._step(
            core.params, kv.pool, jnp.asarray(tbl), jnp.asarray(toks),
            jnp.asarray(kv_len), jnp.asarray(q_len))
        for i in range(lanes):
            if q_len[i] and rows[i] == len(prompts[i]):
                final[i] = np.asarray(logits[i])
    return final


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("chunk_factor", ["1", "ps", "3ps"])
def test_chunked_prefill_matches_contiguous_oracle(chunk_factor, kv_quant):
    """Chunked paged prefill == the contiguous ``prefill`` oracle on the
    final-position logits, over ragged prompt lengths, chunk sizes
    {1, ps, 3·ps}, GQA heads (the smoke config is 4 query / 2 KV) and int8
    pools.  Greedy argmax must agree exactly; logits to float tolerance."""
    cfg, params = build(kv_quant=kv_quant)
    ps = 8
    chunk = {"1": 1, "ps": ps, "3ps": 3 * ps}[chunk_factor]
    m = build_model(cfg)
    lens = (19, 7, 25)                       # ragged, page-straddling
    prompts = prompts_for(cfg, 5, lens)

    core = EngineCore(cfg, params, lanes=len(prompts), page_size=ps,
                      num_pages=16, chunk_size=chunk)
    got = _drive_chunked_prefill(m, params, core, prompts, chunk)

    for i, p in enumerate(prompts):
        caches = m.init_cache(1, len(p))
        want, _ = m.prefill(params, {"tokens": jnp.asarray(p)[None]}, caches)
        want = np.asarray(want[0])
        np.testing.assert_allclose(got[i], want, atol=2e-4, rtol=2e-4,
                                   err_msg=f"lane {i} (len {len(p)})")
        assert int(np.argmax(got[i])) == int(np.argmax(want))


# ------------------------------------------------------------- fairness --

def test_token_budget_keeps_decode_ahead_of_prefill():
    """With a step token budget, resident decode lanes always get their one
    token before prefill chunks spend the rest — a long prompt streams
    through spare capacity instead of starving decodes."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                     chunk_size=8, step_tokens=5)
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 1, (4,))[0],
                       max_new=12))
    eng.step()                              # uid 0 resident, decoding
    eng.submit(Request(uid=1, prompt=prompts_for(cfg, 2, (30,))[0],
                       max_new=2))
    saw_budgeted_mix = False
    while eng.scheduler.has_work():
        out = eng.step()
        assert out.prefill_tokens + out.decode_tokens <= 5
        if out.mixed:
            assert out.decode_tokens >= 1
            assert out.prefill_tokens <= 4  # budget minus the decode lane
            saw_budgeted_mix = True
    assert saw_budgeted_mix
    assert len(by_uid(eng.finished)[0]) == 12


# ------------------------------------------------- sliding-window paging --

@pytest.mark.parametrize("page_size", [4, 8])
def test_sliding_window_config_pages_when_window_fits(page_size):
    """gemma2-style local+global stacks serve through EngineCore when
    page_size ≤ window (no ring buffer materialises inside a page — the
    pageability probe must not look past page_size, so the window == page
    boundary works too) and stay token-identical to the slot engine,
    window masking included."""
    cfg, params = build("gemma2-9b-smoke")
    assert cfg.window == 8

    def submit_all(eng):
        for i, p in enumerate(prompts_for(cfg, 5, (4, 14, 9))):
            eng.submit(Request(uid=i, prompt=p, max_new=(6, 4, 8)[i]))

    slot = ServingEngine(cfg, params, slots=2, max_len=64)
    submit_all(slot)
    want = by_uid(slot.run())
    core = EngineCore(cfg, params, lanes=2, page_size=page_size,
                      num_pages=96 // page_size, chunk_size=8)
    submit_all(core)
    assert by_uid(core.run()) == want


# ------------------------------------------------------------ rejection --

def test_empty_prompt_rejected_at_submit():
    """A zero-token prompt can never be scheduled (known() == 0 plans
    q_len = 0 forever) — it must be rejected at submit, not wedge a lane."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.array([], np.int32), max_new=4))
    assert not eng.scheduler.has_work()


# ------------------------------------------------------------ StepOutput --

def test_step_output_accounting():
    """StepOutput's lane/token accounting adds up against the request
    bookkeeping."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                     chunk_size=8)
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 9, (11,))[0],
                       max_new=3))
    out = eng.step()
    assert isinstance(out, StepOutput)
    assert out.lanes == 1 and out.prefill_tokens == 8  # first chunk of 11
    assert out.tokens == {} and not out.finished
    out = eng.step()                        # final 3 prompt rows → sample
    assert out.prefill_tokens == 3 and len(out.tokens) == 1
    eng.run()
    assert len(eng.finished[0].tokens) == 3

    # Phase accounting is by remaining-known, not q_len: a chunk_size=1
    # engine still reports its prompt streaming as prefill tokens.
    eng1 = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=16,
                      chunk_size=1)
    eng1.submit(Request(uid=0, prompt=prompts_for(cfg, 9, (5,))[0],
                        max_new=2))
    outs = []
    while eng1.scheduler.has_work():
        outs.append(eng1.step())
    assert sum(o.prefill_tokens for o in outs) == 4   # rows 0..3 of 5
    assert sum(o.decode_tokens for o in outs) == 2    # the 2 sampling steps
