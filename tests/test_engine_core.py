"""EngineCore + Scheduler: the request-level serving API, both packings.

Covers the serving contracts: mixed chunked-prefill + decode batches are
token-identical to the PR-2 engines (float and int8) in BOTH step packings
— the PR-3 right-aligned (lanes, C) block and the token-level ragged
stream, which is additionally proven token-identical to the padded step on
the same traces; a stream of distinct prompt lengths compiles O(1) step
functions in either mode (never keyed by prompt length); the ragged step
graph contains no (lanes, C)-padded intermediate (jaxpr walk); ragged
packing never exceeds the token budget, keeps cu_seqlens/lane ids
consistent, and preserves decode-first fairness and token-identical
preemption-resume; chunked paged prefill matches the contiguous prefill
oracle over ragged lengths, chunk sizes {1, ps, 3·ps}, GQA and int8 pools;
sliding-window configs page when page_size ≤ window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EngineCore, Request, RequestState, ServingEngine,
                           StepOutput)


def build(name="deepseek-7b-smoke", **replace):
    cfg = get_config(name)
    if replace:
        cfg = cfg.replace(**replace)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def prompts_for(cfg, seed, lens):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, lp).astype(np.int32)
            for lp in lens]


def by_uid(done):
    return {r.uid: r.tokens for r in done}


# --------------------------------------------------- mixed-batch identity --

@pytest.mark.parametrize("mode", ["padded", "ragged"])
@pytest.mark.parametrize("kv_quant", [False, True])
def test_step_token_identical_to_pr2_engines(kv_quant, mode):
    """EngineCore.step() with mixed chunked-prefill + decode lanes emits the
    same greedy token streams as the slot-contiguous engine on the same
    request trace (lowest-index tie-break), float and int8, in both the
    padded-block and ragged-stream packings.  Prompt lengths straddle chunk
    and page boundaries so early requests are decoding while later ones
    still stream prefill chunks — the mixed batch is exercised, not just
    reachable."""
    cfg, params = build(kv_quant=kv_quant)
    lens = (3, 21, 9, 14, 6)
    news = (7, 5, 9, 4, 6)

    def submit_all(eng):
        for i, p in enumerate(prompts_for(cfg, 13, lens)):
            eng.submit(Request(uid=i, prompt=p, max_new=news[i]))

    slot = ServingEngine(cfg, params, slots=3, max_len=64)
    submit_all(slot)
    want = by_uid(slot.run())

    core = EngineCore(cfg, params, lanes=3, page_size=8, num_pages=24,
                      chunk_size=8, mode=mode)
    submit_all(core)
    outs = []
    while core.scheduler.has_work():
        outs.append(core.step())
    assert by_uid(core.finished) == want
    assert any(o.mixed for o in outs), "no step mixed prefill with decode"


@pytest.mark.parametrize("kv_quant", [False, True])
def test_ragged_step_token_identical_to_padded_step(kv_quant):
    """The ragged packed-stream step vs the PR-3 padded step as oracle, on
    the same mixed prefill+decode traces (float and int8): identical token
    streams, and the ragged run's padding efficiency (live rows / computed
    rows) strictly dominates the padded run's."""
    cfg, params = build(kv_quant=kv_quant)
    lens = (5, 27, 11, 18, 8, 3)
    news = (6, 4, 8, 3, 7, 5)

    def run(mode):
        eng = EngineCore(cfg, params, lanes=3, page_size=8, num_pages=24,
                         chunk_size=8, mode=mode)
        for i, p in enumerate(prompts_for(cfg, 31, lens)):
            eng.submit(Request(uid=i, prompt=p, max_new=news[i]))
        outs = []
        while eng.scheduler.has_work():
            outs.append(eng.step())
        return by_uid(eng.finished), outs

    want, outs_p = run("padded")
    got, outs_r = run("ragged")
    assert got == want, "ragged step diverged from the padded oracle"
    assert any(o.mixed for o in outs_r), "no ragged step mixed the phases"

    def eff(outs):
        return (sum(o.live_rows for o in outs)
                / max(sum(o.padded_rows for o in outs), 1))

    assert eff(outs_r) > eff(outs_p), (eff(outs_r), eff(outs_p))
    assert eff(outs_r) >= 0.9, f"ragged packing wasted rows: {eff(outs_r)}"


# ------------------------------------------------------- compile counting --

@pytest.mark.parametrize("mode", ["padded", "ragged"])
def test_distinct_prompt_lengths_compile_O1_step_functions(mode):
    """The recompile fallout of the per-prompt-length b=1 prefill is gone in
    both packings: step shapes are keyed by (width bucket × power-of-two
    table width, held at its high-water mark) — the padded step's widths
    are {1, C}, the ragged step's the scheduler's token-bucket set — never
    by prompt length.  A first stream warms every reachable combo; a
    second stream of *new* distinct lengths then traces nothing at all
    (the PR-2 engines compiled one prefill per length)."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=64,
                     chunk_size=8, mode=mode)

    def serve(lens, seed):
        for i, p in enumerate(prompts_for(cfg, seed, lens)):
            eng.submit(Request(uid=seed * 100 + i, prompt=p, max_new=2))
        eng.run()
        eng.finished.clear()

    # Warm every reachable (width bucket × table width) combo: lengths
    # 2..22 cover all chunk remainders at table widths 1/2/4, and 24/27/29
    # add the full-chunk and remainder cases at width 4.
    serve(tuple(range(2, 23)) + (24, 27, 29), seed=1)
    traced = eng.trace_count
    # O(1) across the bucket set: bounded by width buckets × table buckets
    # ({1, 2, 4} for this pool), and never by the number of prompt lengths.
    widths = 2 if mode == "padded" else len(eng.scheduler.token_buckets)
    assert traced <= 3 * widths, (traced, widths)
    serve((23, 25, 26, 28, 30), seed=2)        # 5 new distinct lengths
    assert eng.trace_count == traced, (
        f"new prompt lengths retraced the step: {traced} → "
        f"{eng.trace_count}")


def test_page_table_width_never_shrinks_across_steps():
    """pack() holds the page-table P axis at its high-water mark: after a
    long resident has grown the table, a later short-only step packs at
    the same width — same trace key — instead of shrinking back.  Without
    the mark, every time the resident mix turned short (fresh arrivals
    mid-serve) the step recompiled at (stream width × smaller table
    width): a multi-second XLA stall in the middle of live traffic for a
    shape the engine had already paid for."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=32,
                     chunk_size=8, mode="ragged")
    widths = []
    inner = eng._ragged

    def spy(p, pool, table, *rest):
        widths.append(int(table.shape[1]))
        return inner(p, pool, table, *rest)

    eng._ragged = spy
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 3, (20,))[0],
                       max_new=8))             # 28 rows → 4 pages resident
    eng.run()
    eng.finished.clear()
    hwm = max(widths)
    assert hwm >= 4, widths
    widths.clear()
    eng.submit(Request(uid=1, prompt=prompts_for(cfg, 4, (4,))[0],
                       max_new=4))             # 1-page request, solo
    eng.run()
    assert widths and set(widths) == {hwm}, (widths, hwm)


# ------------------------------------------------------------ preemption --

@pytest.mark.parametrize("mode", ["padded", "ragged"])
def test_preempted_request_resumes_token_identical(mode):
    """Fill the pool with a long-running request, admit a longer prompt;
    the pool exhausts mid-flight, the youngest resident is evicted
    (recompute preemption) and later resumes — and every request's token
    stream is identical to an uncontended (solo, full-pool) run.  Holds in
    both packings: ragged trim/packing changes step shapes, never the
    replayed stream."""
    cfg, params = build()
    specs = [(4, 26), (12, 14)]            # (prompt_len, max_new)
    prompts = prompts_for(cfg, 21, [lp for lp, _ in specs])

    solo = {}
    for uid, (lp, mn) in enumerate(specs):
        eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=16,
                         chunk_size=4, mode=mode)
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
        solo[uid] = eng.run()[0].tokens

    # contended: 8 pages cannot hold both peaks (8 + 7 pages)
    eng = EngineCore(cfg, params, lanes=2, page_size=4, num_pages=8,
                     chunk_size=4, mode=mode)
    preempted_seen = []
    for uid, (lp, mn) in enumerate(specs):
        eng.submit(Request(uid=uid, prompt=prompts[uid], max_new=mn))
    while eng.scheduler.has_work():
        out = eng.step()
        preempted_seen.extend(out.preempted)
    assert preempted_seen, "pool contention never triggered an eviction"
    got = by_uid(eng.finished)
    assert got == solo, "preempted request did not resume token-identically"
    assert eng.pages_in_use == 0
    # the evicted request went through the PREEMPTED state and finished
    evicted = eng.finished[-1] if eng.finished[-1].uid in preempted_seen \
        else eng.finished[0]
    assert evicted.state is RequestState.FINISHED


def test_oldest_resident_is_never_evicted():
    """Eviction picks strictly younger residents, so the oldest request
    always runs to completion — the progress guarantee behind
    preemption-by-eviction."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=3, page_size=4, num_pages=8,
                     chunk_size=4)
    for i, p in enumerate(prompts_for(cfg, 3, (6, 6, 6))):
        eng.submit(Request(uid=i, prompt=p, max_new=20))
    first_done = None
    while eng.scheduler.has_work():
        out = eng.step()
        assert 0 not in out.preempted, "oldest request was evicted"
        if first_done is None and out.finished:
            first_done = out.finished[0]
    assert first_done == 0      # FCFS: the oldest finishes first here


# ------------------------------------------- chunked-prefill equivalence --

def _drive_chunked_prefill(model, params, core, prompts, chunk):
    """Manually stream ragged prompts through the unified chunk step (the
    exact EngineCore dataflow) and return each lane's final-row logits."""
    kv = core.kv
    lanes = len(prompts)
    pages = [[] for _ in prompts]
    rows = [0] * lanes
    final = [None] * lanes
    while any(rows[i] < len(prompts[i]) for i in range(lanes)):
        q_len = np.zeros((lanes,), np.int32)
        kv_len = np.zeros((lanes,), np.int32)
        toks = np.zeros((lanes, chunk), np.int32)
        for i, p in enumerate(prompts):
            c = min(chunk, len(p) - rows[i])
            if c <= 0:
                continue
            while len(pages[i]) < kv.pages_needed(rows[i] + c):
                pages[i].append(kv.alloc())
            toks[i, chunk - c:] = p[rows[i]:rows[i] + c]
            q_len[i] = c
            kv_len[i] = rows[i] + c
            rows[i] += c
        width = 1 << max(max(len(pg) for pg in pages) - 1, 0).bit_length()
        tbl = np.full((lanes, width), kv.scratch, np.int32)
        for i, pg in enumerate(pages):
            tbl[i, :len(pg)] = pg
        logits, kv.pool = core._step(
            core.params, kv.pool, jnp.asarray(tbl), jnp.asarray(toks),
            jnp.asarray(kv_len), jnp.asarray(q_len))
        for i in range(lanes):
            if q_len[i] and rows[i] == len(prompts[i]):
                final[i] = np.asarray(logits[i])
    return final


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("chunk_factor", ["1", "ps", "3ps"])
def test_chunked_prefill_matches_contiguous_oracle(chunk_factor, kv_quant):
    """Chunked paged prefill == the contiguous ``prefill`` oracle on the
    final-position logits, over ragged prompt lengths, chunk sizes
    {1, ps, 3·ps}, GQA heads (the smoke config is 4 query / 2 KV) and int8
    pools.  Greedy argmax must agree exactly; logits to float tolerance."""
    cfg, params = build(kv_quant=kv_quant)
    ps = 8
    chunk = {"1": 1, "ps": ps, "3ps": 3 * ps}[chunk_factor]
    m = build_model(cfg)
    lens = (19, 7, 25)                       # ragged, page-straddling
    prompts = prompts_for(cfg, 5, lens)

    core = EngineCore(cfg, params, lanes=len(prompts), page_size=ps,
                      num_pages=16, chunk_size=chunk)
    got = _drive_chunked_prefill(m, params, core, prompts, chunk)

    for i, p in enumerate(prompts):
        caches = m.init_cache(1, len(p))
        want, _ = m.prefill(params, {"tokens": jnp.asarray(p)[None]}, caches)
        want = np.asarray(want[0])
        np.testing.assert_allclose(got[i], want, atol=2e-4, rtol=2e-4,
                                   err_msg=f"lane {i} (len {len(p)})")
        assert int(np.argmax(got[i])) == int(np.argmax(want))


# ------------------------------------------------------------- fairness --

@pytest.mark.parametrize("mode", ["padded", "ragged"])
def test_token_budget_keeps_decode_ahead_of_prefill(mode):
    """With a step token budget, resident decode lanes always get their one
    token before prefill chunks spend the rest — a long prompt streams
    through spare capacity instead of starving decodes.  Ragged trim only
    ever shrinks prefill chunks, so the guarantee survives packing."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                     chunk_size=8, step_tokens=5, mode=mode)
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 1, (4,))[0],
                       max_new=12))
    eng.step()                              # uid 0 resident, decoding
    eng.submit(Request(uid=1, prompt=prompts_for(cfg, 2, (30,))[0],
                       max_new=2))
    saw_budgeted_mix = False
    while eng.scheduler.has_work():
        out = eng.step()
        assert out.prefill_tokens + out.decode_tokens <= 5
        if out.mixed:
            assert out.decode_tokens >= 1
            assert out.prefill_tokens <= 4  # budget minus the decode lane
            saw_budgeted_mix = True
    assert saw_budgeted_mix
    assert len(by_uid(eng.finished)[0]) == 12


# ------------------------------------------------- sliding-window paging --

@pytest.mark.parametrize("page_size", [4, 8])
def test_sliding_window_config_pages_when_window_fits(page_size):
    """gemma2-style local+global stacks serve through EngineCore when
    page_size ≤ window (no ring buffer materialises inside a page — the
    pageability probe must not look past page_size, so the window == page
    boundary works too) and stay token-identical to the slot engine,
    window masking included."""
    cfg, params = build("gemma2-9b-smoke")
    assert cfg.window == 8

    def submit_all(eng):
        for i, p in enumerate(prompts_for(cfg, 5, (4, 14, 9))):
            eng.submit(Request(uid=i, prompt=p, max_new=(6, 4, 8)[i]))

    slot = ServingEngine(cfg, params, slots=2, max_len=64)
    submit_all(slot)
    want = by_uid(slot.run())
    core = EngineCore(cfg, params, lanes=2, page_size=page_size,
                      num_pages=96 // page_size, chunk_size=8)
    submit_all(core)
    assert by_uid(core.run()) == want


# ------------------------------------------------------------ rejection --

def test_empty_prompt_rejected_at_submit():
    """A zero-token prompt can never be scheduled (known() == 0 plans
    q_len = 0 forever) — it must be rejected at submit, not wedge a lane."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.array([], np.int32), max_new=4))
    assert not eng.scheduler.has_work()


# ----------------------------------------------- ragged graph guarantees --

def _sampling_args(lanes):
    """All-greedy in-step sampling arrays for tracing the ragged step."""
    return (jnp.zeros((lanes,), jnp.float32), jnp.zeros((lanes,), jnp.int32),
            jnp.ones((lanes,), jnp.float32), jnp.zeros((lanes,), jnp.uint32),
            jnp.zeros((lanes,), jnp.int32))


def test_ragged_graph_has_no_padded_intermediate():
    """The ragged step graph must never materialise a (lanes, C)-padded
    block: every intermediate of the traced step is checked for an
    adjacent (lanes, chunk) dim pair.  lanes=3 × chunk=24 shares no
    adjacent pair with any smoke-config dimension or the T=48 stream, so a
    hit can only be the padded block.  The padded step itself is the
    sanity check that the detector fires."""
    from tests.test_paged_serving import _jaxpr_shapes

    cfg, params = build()
    lanes, chunk, ps = 3, 24, 8
    eng = EngineCore(cfg, params, lanes=lanes, page_size=ps, num_pages=32,
                     chunk_size=chunk)
    t, pw = 48, 4                       # 3 decodes + a 45-token chunk share
    cu = jnp.asarray([0, 1, 2, 48, 48], jnp.int32)      # (lanes + 2,)
    jaxpr = jax.make_jaxpr(eng._ragged)(
        eng.params, eng.kv.pool,
        jnp.full((t, pw), eng.kv.scratch, jnp.int32),
        jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
        jnp.zeros((lanes,), jnp.int32), cu, *_sampling_args(lanes))

    def padded_pairs(shapes):
        return [s for s in shapes
                if any(s[i] == lanes and s[i + 1] == chunk
                       for i in range(len(s) - 1))]

    bad = padded_pairs(_jaxpr_shapes(jaxpr.jaxpr))
    assert not bad, f"(lanes, C)-padded intermediate in ragged graph: {bad}"

    # sanity: the detector does catch the padded step's block
    padded = jax.make_jaxpr(eng._step)(
        eng.params, eng.kv.pool,
        jnp.full((lanes, pw), eng.kv.scratch, jnp.int32),
        jnp.zeros((lanes, chunk), jnp.int32),
        jnp.zeros((lanes,), jnp.int32), jnp.zeros((lanes,), jnp.int32))
    assert padded_pairs(_jaxpr_shapes(padded.jaxpr))


# ------------------------------------------------ scheduler pack properties --

def _sim_engine(sched, batch):
    """Advance scheduler state the way EngineCore._finish would, without
    running any jax compute (greedy tokens faked as 0)."""
    for p in batch.plans:
        run = p.run
        sample = p.sample
        run.rows += p.q_len
        if not sample:
            continue
        run.req.tokens.append(0)
        if len(run.req.tokens) >= run.req.max_new:
            sched.finish(run)


def _make_scheduler(num_pages=64, lanes=3, chunk=8, step_tokens=None):
    from repro.serving import PagedKVCache, Scheduler
    cfg = get_config("deepseek-7b-smoke")
    kv = PagedKVCache(build_model(cfg), num_pages, 8)
    return Scheduler(kv, lanes=lanes, chunk_size=chunk,
                     step_tokens=step_tokens), cfg


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_ragged_packing_properties(seed):
    """Every schedule_ragged() batch, across a random request stream:
    packing never exceeds the token budget; the width is the tightest
    bucket; cu_seqlens is monotone and consistent with lane ids, positions,
    tokens and per-token table rows; decode lanes are never trimmed."""
    rng = np.random.default_rng(seed)
    sched, cfg = _make_scheduler()
    for uid in range(int(rng.integers(2, 7))):
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(1, 30))).astype(np.int32),
            max_new=int(rng.integers(1, 8))))
    steps = 0
    while sched.has_work():
        steps += 1
        assert steps < 500, "scheduler did not drain"
        decode_runs = [r for r in sched.running if r.remaining() == 1]
        rows_before = {r.ticket: r.rows for r in sched.running}
        batch, _ = sched.schedule_ragged()
        plans, cu = batch.plans, batch.cu_seqlens

        # budget + bucket tightness
        assert batch.live == sum(p.q_len for p in plans) == int(cu[-1])
        assert batch.live <= sched.step_tokens
        assert batch.width in sched.token_buckets
        assert batch.width >= max(batch.live, 1)
        tighter = [w for w in sched.token_buckets
                   if max(batch.live, 1) <= w < batch.width]
        assert not tighter, f"width {batch.width} not tightest: {tighter}"

        # cu_seqlens ↔ lane_id ↔ pos ↔ tokens ↔ table consistency
        assert cu[0] == 0 and np.all(np.diff(cu) >= 1)
        for i, p in enumerate(plans):
            lo, hi = int(cu[i]), int(cu[i + 1])
            assert hi - lo == p.q_len
            assert np.all(batch.lane_id[lo:hi] == i)
            start = rows_before.get(p.run.ticket, 0)  # 0: admitted this step
            np.testing.assert_array_equal(
                batch.pos[lo:hi], start + np.arange(p.q_len))
            np.testing.assert_array_equal(
                batch.tokens[lo:hi], p.run.next_tokens(p.q_len))
            npg = len(p.run.pages)
            assert npg >= sched.kv.pages_needed(start + p.q_len)
            np.testing.assert_array_equal(
                batch.table[lo:hi, :npg],
                np.tile(np.asarray(p.run.pages, np.int32), (p.q_len, 1)))
            assert np.all(batch.table[lo:hi, npg:] == sched.kv.scratch)
        assert np.all(batch.lane_id[batch.live:] == -1)
        assert np.all(batch.table[batch.live:] == sched.kv.scratch)

        # decode-first, trim-exempt: every resident decode lane runs intact
        for r in decode_runs:
            if r in sched.running:       # not evicted while planning
                mine = [p for p in plans if p.run is r]
                assert mine and mine[0].q_len == 1, \
                    "decode lane trimmed or starved by ragged packing"
        _sim_engine(sched, batch)
    assert sched.kv.free_pages == sched.kv.num_pages


def test_trim_never_starves_a_prefill_lane():
    """Regression: 8 decode lanes exactly fill a bucket (floor = 8) while a
    2-token prefill tail wants the other 2 tokens.  A trim that zeroed the
    tail would see the identical plan every step and starve it for the
    decodes' whole lifetime; the progress guarantee (every planned lane
    keeps ≥ 1 token, else pad up) must finish it promptly."""
    rng = np.random.default_rng(0)
    sched, cfg = _make_scheduler(num_pages=64, lanes=9, chunk=16)
    for uid in range(8):
        sched.submit(Request(uid=uid, prompt=np.array([1], np.int32),
                             max_new=40))
    sched.submit(Request(
        uid=8, prompt=rng.integers(0, cfg.vocab_size, 2).astype(np.int32),
        max_new=1))
    for _ in range(4):          # uid 8 needs ≤ 2 planned steps to finish
        batch, _ = sched.schedule_ragged()
        assert batch.live <= sched.step_tokens
        _sim_engine(sched, batch)
        if not any(r.req.uid == 8 for r in sched.running):
            break
    assert not any(r.req.uid == 8 for r in sched.running), \
        "prefill lane starved by trim-to-bucket"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_ragged_packing_under_preemption(seed):
    """A pool far too small for the offered load: schedule_ragged must keep
    its packing invariants while evicting — evicted requests rewind to row
    0 and hold no pages, and the stream drains completely."""
    rng = np.random.default_rng(seed)
    sched, cfg = _make_scheduler(num_pages=8, lanes=3, chunk=4)
    for uid in range(4):
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, 16))).astype(np.int32),
            max_new=int(rng.integers(4, 12))))
    evictions = 0
    steps = 0
    while sched.has_work():
        steps += 1
        assert steps < 2000, "scheduler did not drain under preemption"
        batch, preempted = sched.schedule_ragged()
        evictions += len(preempted)
        assert batch.live <= sched.step_tokens
        assert batch.width in sched.token_buckets
        for r in sched.waiting:
            assert r.rows == 0 and r.pages == [], \
                "evicted request kept pages or cursor state"
        _sim_engine(sched, batch)
    assert sched.kv.free_pages == sched.kv.num_pages


# ------------------------------------------------------------ StepOutput --

def test_step_output_accounting():
    """StepOutput's lane/token accounting adds up against the request
    bookkeeping."""
    cfg, params = build()
    eng = EngineCore(cfg, params, lanes=2, page_size=8, num_pages=16,
                     chunk_size=8)
    eng.submit(Request(uid=0, prompt=prompts_for(cfg, 9, (11,))[0],
                       max_new=3))
    out = eng.step()
    assert isinstance(out, StepOutput)
    assert out.lanes == 1 and out.prefill_tokens == 8  # first chunk of 11
    assert out.tokens == {} and not out.finished
    out = eng.step()                        # final 3 prompt rows → sample
    assert out.prefill_tokens == 3 and len(out.tokens) == 1
    eng.run()
    assert len(eng.finished[0].tokens) == 3

    # Phase accounting is by remaining-known, not q_len: a chunk_size=1
    # engine still reports its prompt streaming as prefill tokens.
    eng1 = EngineCore(cfg, params, lanes=1, page_size=8, num_pages=16,
                      chunk_size=1)
    eng1.submit(Request(uid=0, prompt=prompts_for(cfg, 9, (5,))[0],
                        max_new=2))
    outs = []
    while eng1.scheduler.has_work():
        outs.append(eng1.step())
    assert sum(o.prefill_tokens for o in outs) == 4   # rows 0..3 of 5
    assert sum(o.decode_tokens for o in outs) == 2    # the 2 sampling steps
