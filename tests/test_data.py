"""Data pipeline: determinism, sharding partition, learnable structure."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image without hypothesis: seeded fallback
    from tests._hypothesis_stub import given, settings, st

from repro.data import DataConfig, TokenPipeline, host_shard


def P(**kw):
    base = dict(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    base.update(kw)
    return TokenPipeline(DataConfig(**base))


def test_deterministic_across_instances():
    a, b = P(), P()
    for step in (0, 1, 17, 100_000):
        x, y = a.shard_batch(step), b.shard_batch(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_different_steps_differ():
    p = P()
    assert not np.array_equal(p.shard_batch(0)["tokens"],
                              p.shard_batch(1)["tokens"])


@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=30, deadline=None)
def test_shards_partition_global_batch(step, num_shards):
    """Property (elasticity/straggler keystone): shards at any host count
    exactly tile the global batch."""
    p = P()
    full = p.global_batch(step)["tokens"]
    parts = [p.shard_batch(step, s, num_shards)["tokens"]
             for s in range(num_shards)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_tokens_in_vocab_range():
    for corpus in ("lm", "copy", "uniform"):
        t = P(corpus=corpus).shard_batch(5)["tokens"]
        assert t.min() >= 0 and t.max() < 97


def test_copy_corpus_structure():
    p = P(corpus="copy")
    b = p.shard_batch(0)
    t = b["tokens"]
    np.testing.assert_array_equal(t[:, 8:], t[:, :8])   # copied half
    assert b["loss_mask"][:, :8].sum() == 0
    assert (b["loss_mask"][:, 8:] == 1).all()


def test_lm_corpus_is_markov():
    """Each token must be one of the Markov successors of its predecessor."""
    p = P(corpus="lm")
    t = p.shard_batch(0)["tokens"]
    succ = p._succ
    for row in t[:4]:
        for a, b in zip(row[:-1], row[1:]):
            assert b in succ[a]


def test_host_shard_arithmetic():
    starts = [host_shard(64, h, 8) for h in range(8)]
    assert starts[0] == (0, 8) and starts[7] == (56, 8)
