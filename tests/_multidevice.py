"""Helper: run a snippet in a subprocess with N placeholder devices.

Device count is locked at first jax init, so multi-chip shard_map tests
cannot run in the main pytest process (which must keep 1 device for the
smoke tests — assignment requirement)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(snippet: str, n_devices: int = 8,
                     timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout
