"""Fault-tolerant distributed training runtime.

The step loop is built from the substrate layers:

  data      deterministic (seed, step, shard) batches — any host can
            recompute any shard (straggler/rejoin mitigation, DESIGN.md §4)
  parallel  param/batch PartitionSpecs; optional bf16+error-feedback
            gradient compression (halves DP all-reduce bytes)
  optim     AdamW (+cosine schedule, clipping, microbatch accumulation)
  checkpoint step-atomic, sharding-independent, async saves

Fault-tolerance contract (exercised by tests/test_runtime.py):
- every step is **idempotent**: (params, opt, step) → (params', opt') with
  batch a pure function of step, so replay-after-restore is exact;
- ``FailureInjector`` raises at configured steps (the CPU stand-in for a
  preempted node); the loop restores the latest checkpoint and resumes —
  losses after recovery equal an uninterrupted run bit-for-bit;
- **elastic**: ``Trainer.restore(mesh=new_mesh)`` re-shards the same
  checkpoint onto a different topology (tested 1-chip → k-chip round trip);
- **bounded staleness** (optional): if a step exceeds
  ``straggler_timeout_ms`` the runtime records it and (if
  ``skip_straggler_steps``) skips the update rather than blocking the
  fleet — the deterministic pipeline makes the skipped batch recomputable
  for audit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import (AdamWConfig, AdamWState, accumulated_grads,
                         adamw_init, adamw_update, cosine_schedule)
from repro.parallel import (batch_specs, compress_with_feedback,
                            feedback_init, param_specs)
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class SimulatedFailure(RuntimeError):
    """A stand-in for a preempted/lost node in single-process tests."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1
    moment_dtype: str = "float32"
    compress_grads: bool = False          # bf16 + error feedback
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    seed: int = 0
    log_every: int = 10
    straggler_timeout_ms: float = 0.0     # 0 = disabled
    skip_straggler_steps: bool = False


class Trainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 train_cfg: TrainConfig = TrainConfig(),
                 mesh: Optional[Mesh] = None):
        self.cfg = train_cfg
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.pipeline = TokenPipeline(data_cfg)
        self.mesh = mesh
        self.opt_cfg = AdamWConfig(
            lr=train_cfg.lr, weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip, moment_dtype=train_cfg.moment_dtype)
        self.schedule = cosine_schedule(train_cfg.lr, train_cfg.warmup,
                                        train_cfg.steps)
        self.ckpt = (AsyncCheckpointer(train_cfg.ckpt_dir,
                                       keep=train_cfg.keep_ckpts)
                     if train_cfg.ckpt_dir else None)
        self.step = 0
        self.params: Any = None
        self.opt_state: Optional[AdamWState] = None
        self.residual: Any = None           # grad-compression error feedback
        self.metrics: list = []
        self.straggler_log: list = []
        self._train_step = self._build_step()

    # ------------------------------------------------------------------ init
    def init(self) -> None:
        self.params = self.model.init(jax.random.PRNGKey(self.cfg.seed))
        self.opt_state = adamw_init(self.params, self.opt_cfg)
        if self.cfg.compress_grads:
            self.residual = feedback_init(self.params)
        if self.mesh is not None:
            from repro.parallel import shard_tree
            pspecs = param_specs(self.params, self.mesh)
            self.params = shard_tree(self.params, pspecs, self.mesh)
        self.step = 0

    # ------------------------------------------------------------ step build
    def _build_step(self) -> Callable:
        model, cfg, opt_cfg = self.model, self.cfg, self.opt_cfg

        def loss_fn(params, batch):
            return model.loss(params, batch)

        def step_fn(params, opt_state, residual, batch, step):
            loss, grads, aux = accumulated_grads(
                loss_fn, params, batch, cfg.microbatches)
            if cfg.compress_grads:
                # bf16 on the DP wire; residual carries the rounding error.
                grads, residual = compress_with_feedback(grads, residual)
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            lr = self.schedule(step)
            params, opt_state, om = adamw_update(
                grads, opt_state, params, opt_cfg, lr=lr)
            metrics = {"loss": loss, "lr": lr, **om}
            return params, opt_state, residual, metrics

        if self.mesh is None:
            return jax.jit(step_fn)
        return jax.jit(step_fn)   # shardings propagate from committed inputs

    # ------------------------------------------------------------- ckpt glue
    def _state_tree(self) -> Dict[str, Any]:
        t = {"params": self.params, "opt": self.opt_state}
        if self.residual is not None:
            t["residual"] = self.residual
        return t

    def save(self) -> None:
        if self.ckpt:
            self.ckpt.save(self.step, self._state_tree(),
                           extra={"step": self.step})

    def restore(self, step: Optional[int] = None,
                mesh: Optional[Mesh] = None) -> int:
        """Restore latest (or given) checkpoint; optionally onto a new mesh."""
        assert self.cfg.ckpt_dir
        if self.params is None:
            self.init()
        ref = self._state_tree()
        mesh = mesh or self.mesh
        specs = None
        if mesh is not None:
            specs = {"params": param_specs(ref["params"], mesh),
                     "opt": AdamWState(
                         step=P(),
                         m=param_specs(ref["opt"].m, mesh),
                         v=param_specs(ref["opt"].v, mesh))}
            if "residual" in ref:
                specs["residual"] = param_specs(ref["residual"], mesh)
        tree, step, _ = restore(self.cfg.ckpt_dir, ref, step=step,
                                mesh=mesh, specs=specs)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.residual = tree.get("residual")
        self.step = step
        self.mesh = mesh
        return step

    # ------------------------------------------------------------------ loop
    def _device_batch(self, step: int) -> Any:
        batch = self.pipeline.shard_batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.mesh is not None:
            specs = batch_specs(batch, self.mesh)
            batch = {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                     for k, v in batch.items()}
        return batch

    def run(self, num_steps: Optional[int] = None,
            injector: Optional[FailureInjector] = None,
            max_restarts: int = 8) -> list:
        """The fault-tolerant loop: on failure, restore + resume."""
        if self.params is None:
            if self.cfg.ckpt_dir and latest_step(self.cfg.ckpt_dir) is not None:
                self.restore()           # auto-resume
            else:
                self.init()
        target = self.cfg.steps if num_steps is None else self.step + num_steps
        restarts = 0
        while self.step < target:
            try:
                self._run_until(target, injector)
            except SimulatedFailure as e:
                restarts += 1
                if restarts > max_restarts or not self.cfg.ckpt_dir:
                    raise
                if self.ckpt:
                    self.ckpt.wait()
                self.restore()           # roll back to last durable state
        if self.ckpt:
            self.save()
            self.ckpt.wait()
        return self.metrics

    def _run_until(self, target: int, injector: Optional[FailureInjector]):
        while self.step < target:
            if injector is not None:
                injector.check(self.step)
            t0 = time.perf_counter()
            batch = self._device_batch(self.step)
            out = self._train_step(self.params, self.opt_state, self.residual,
                                   batch, jnp.asarray(self.step, jnp.int32))
            params, opt_state, residual, metrics = out
            dt_ms = (time.perf_counter() - t0) * 1e3
            if (self.cfg.straggler_timeout_ms
                    and dt_ms > self.cfg.straggler_timeout_ms):
                self.straggler_log.append((self.step, dt_ms))
                if self.cfg.skip_straggler_steps:
                    self.step += 1       # bounded staleness: drop the update
                    continue
            self.params, self.opt_state, self.residual = (params, opt_state,
                                                          residual)
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["ms"] = self.step, dt_ms
            self.metrics.append(m)
            self.step += 1
            if self.ckpt and self.step % self.cfg.ckpt_every == 0:
                self.save()
        return self.metrics
