from repro.runtime.trainer import (FailureInjector, SimulatedFailure,
                                   TrainConfig, Trainer)

__all__ = ["Trainer", "TrainConfig", "FailureInjector", "SimulatedFailure"]
