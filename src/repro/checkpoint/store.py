"""Sharding-independent, step-atomic checkpointing.

Layout (one directory per step)::

    <dir>/step_00000042/
        arrays.npz          # leaf path → full (unsharded) array
        manifest.json       # step, leaf paths, shapes, dtypes, sha256, extra

Properties (DESIGN.md §4):
- **atomic**: written into ``step_X.tmp-<pid>`` then ``os.replace``d into
  place — a crash mid-write can never produce a half-checkpoint that
  ``latest_step`` would pick up;
- **verified**: the manifest carries a sha256 per leaf; ``restore`` checks
  it (corrupt checkpoints are detected, and the loop falls back to the
  previous step);
- **sharding-independent / elastic**: leaves are stored by *logical path +
  global shape*.  ``restore`` re-materialises them onto *any* mesh via
  device_put with the target sharding — scale up/down between runs is a
  tested path, not an accident;
- **async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with the next
  training steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


# --------------------------------------------------------------------------
# pytree ↔ flat dict  (paths are stable logical names)
# --------------------------------------------------------------------------

def flatten_tree(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in kp:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        flat["/".join(parts)] = np.asarray(jax.device_get(leaf))
    return flat


def unflatten_into(reference: Any, flat: Dict[str, np.ndarray]) -> Any:
    """Map flat path→array onto the structure of ``reference``."""
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for kp, ref_leaf in leaves_kp:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        path = "/".join(parts)
        if path not in flat:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = flat[path]
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs "
                f"expected {ref_leaf.shape}")
        want = np.dtype(ref_leaf.dtype)
        if arr.dtype.kind == "V":
            # npz round-trips ml_dtypes (bfloat16 …) as raw void bytes;
            # reinterpret — bit-exact by construction.
            assert arr.dtype.itemsize == want.itemsize, (arr.dtype, want)
            arr = arr.view(want)
        else:
            arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# save / restore
# --------------------------------------------------------------------------

def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = flatten_tree(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": _sha(v)} for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    s = steps(ckpt_dir)
    return s[-1] if s else None


def restore(ckpt_dir: str, reference: Any, step: Optional[int] = None,
            mesh=None, specs: Any = None, verify: bool = True
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore onto the structure of ``reference`` (tree of arrays or SDS).

    With ``mesh``+``specs``, leaves are device_put with the target sharding —
    this is the elastic re-mesh path.  Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["leaves"].items():
            if _sha(flat[k]) != meta["sha256"]:
                raise IOError(f"checkpoint corruption: sha mismatch at {k}")
    tree = unflatten_into(reference, flat)
    if mesh is not None and specs is not None:
        from repro.parallel.sharding import shard_tree
        tree = shard_tree(tree, specs, mesh)
    return tree, step, manifest.get("extra", {})


def retain(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    for s in steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


# --------------------------------------------------------------------------
# async writer
# --------------------------------------------------------------------------

class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        flat = flatten_tree(tree)      # host snapshot (blocks only on D2H)

        def _write():
            try:
                save(self.ckpt_dir, step, flat, extra)
                retain(self.ckpt_dir, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
