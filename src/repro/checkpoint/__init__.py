from repro.checkpoint.store import (AsyncCheckpointer, flatten_tree,
                                    latest_step, restore, retain, save, steps,
                                    unflatten_into)

__all__ = ["save", "restore", "latest_step", "steps", "retain",
           "AsyncCheckpointer", "flatten_tree", "unflatten_into"]
