"""Production mesh construction.

``make_production_mesh`` is a function (importing this module never touches
jax device state).  Meshes:

  single-pod   (16, 16)      axes ("data", "model")         — 256 chips
  multi-pod    (2, 16, 16)   axes ("pod", "data", "model")  — 512 chips

The "pod" axis is the slowest (DCN between pods); "model" is innermost (ICI
ring) — tensor-parallel collectives stay on-pod, only data-parallel gradient
reductions cross the DCN, matching the v5e network hierarchy.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))
