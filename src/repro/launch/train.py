"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-process entry point; on a real cluster each host runs this with
``jax.distributed.initialize()`` (flag --distributed) and the same config —
the deterministic data pipeline hands every host its shard by
(step, host_id), so no coordinator is needed (DESIGN.md §4).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="config id; append -smoke for the reduced variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--corpus", default="lm", choices=["lm", "copy", "uniform"])
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, corpus=args.corpus)
    tcfg = TrainConfig(steps=args.steps, lr=args.lr,
                       microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       compress_grads=args.compress_grads)
    mesh = make_host_mesh() if args.mesh else None
    trainer = Trainer(cfg, dcfg, tcfg, mesh=mesh)
    metrics = trainer.run()
    for m in metrics[:: max(len(metrics) // 20, 1)]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['ms']:.0f} ms")
    print(f"final loss: {metrics[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
