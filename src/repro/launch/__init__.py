"""Launch layer: production mesh, multi-pod dry-run, train/serve CLIs.

NOTE: do not import ``repro.launch.dryrun`` from library code — it sets
XLA_FLAGS at import (placeholder devices) and must only run as __main__.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
