"""HLO-text analysis: collective traffic and FLOP accounting for §Roofline.

Two gaps in ``compiled.cost_analysis()`` force text analysis:

1. it has no collective-bytes concept at all;
2. it visits each ``while`` body ONCE — a scan-over-layers program reports
   ~1/trip_count of its real FLOPs/bytes (measured 400× low on grok-1).

So we parse the post-optimisation HLO:

- build a module-wide symbol table (instruction name → result shape) —
  post-opt HLO prints operand *names* without inline shapes;
- per computation, sum collective operand bytes (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute) and matmul FLOPs
  (``dot`` instructions: 2 · result_elems · contraction size);
- walk the call graph; a ``while`` body's totals are multiplied by a trip
  count.  Trip counts aren't printed in HLO, but the framework knows its
  loop nest (layer scan = #periods, chunk scans = L/chunk, microbatches) —
  the caller passes ``trip_hints`` by nesting depth.

Wire bytes use standard ring-algorithm factors:

    all-reduce       2·(n−1)/n · operand bytes
    all-gather       (n−1)/n · result bytes
    reduce-scatter   (n−1)/n · operand bytes
    all-to-all       (n−1)/n · operand bytes
    collective-permute   1 · operand bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
          "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
          "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5,
          "u4": 0.5}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\).*?body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_DOT_RE = re.compile(r"=\s*(.+?)\s+dot\(")
_LHS_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def shape_bytes(shape_str: str) -> float:
    """Total bytes of a possibly-tuple HLO shape string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_elems(shape_str: str) -> float:
    n = 1.0
    for d in shape_dims(shape_str):
        n *= d
    return n


def _args_of(line: str, start: int) -> List[str]:
    """Split the operand list starting right after '(' at ``start``."""
    depth, i, buf, out = 1, start, [], []
    while i < len(line) and depth:
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
        i += 1
    if buf:
        out.append("".join(buf))
    return [a.strip() for a in out]


def _operand_bytes(arg: str, defs: Dict[str, str]) -> float:
    if "[" in arg:                       # inline shape (pre-opt HLO)
        return shape_bytes(arg)
    name = arg.lstrip("%")
    return shape_bytes(defs.get(name, ""))


def _operand_shape(arg: str, defs: Dict[str, str]) -> str:
    if "[" in arg:
        return arg
    return defs.get(arg.lstrip("%"), "")


def parse_defs(hlo: str) -> Dict[str, str]:
    defs: Dict[str, str] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)
    return defs


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    name: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            name = m.group(1)
            comps[name] = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = comps[name]
            continue
        if name is not None:
            comps[name].append(line)
            if line.strip() == "}":
                name = None
    return comps


def _totals_of(lines: List[str], defs: Dict[str, str]) -> Dict[str, float]:
    """Collective bytes + dot FLOPs for one computation body (no callees)."""
    out = {f"op_{k}": 0.0 for k in _COLLECTIVES}
    out.update({f"wire_{k}": 0.0 for k in _COLLECTIVES})
    out["flops"] = 0.0
    for line in lines:
        mc = _COLL_RE.search(line)
        if mc:
            result_shape, kind = mc.group(1), mc.group(2)
            args = _args_of(line, mc.end())
            operand_bytes = sum(_operand_bytes(a, defs) for a in args
                                if a and not a[0].isdigit())
            result_bytes = shape_bytes(result_shape)
            gm = _GROUPS_RE.search(line)
            n = max(len(gm.group(1).split(",")) if gm else 2, 2)
            out[f"op_{kind}"] += operand_bytes
            if kind == "all-reduce":
                out[f"wire_{kind}"] += 2.0 * (n - 1) / n * operand_bytes
            elif kind == "all-gather":
                out[f"wire_{kind}"] += (n - 1) / n * result_bytes
            elif kind in ("reduce-scatter", "all-to-all"):
                out[f"wire_{kind}"] += (n - 1) / n * operand_bytes
            else:
                out[f"wire_{kind}"] += operand_bytes
            continue
        md = _DOT_RE.search(line)
        if md:
            result_elems = _shape_elems(md.group(1))
            args = _args_of(line, md.end())
            lhs_shape = _operand_shape(args[0], defs) if args else ""
            dims = shape_dims(lhs_shape)
            ml = _LHS_DIMS_RE.search(line)
            k = 1.0
            if ml and dims:
                for ix in ml.group(1).split(","):
                    if ix and int(ix) < len(dims):
                        k *= dims[int(ix)]
            out["flops"] += 2.0 * result_elems * k
    return out


def _while_bodies(lines: List[str]) -> List[str]:
    return [m.group(1) for line in lines
            for m in [_WHILE_RE.search(line)] if m]


def _callees(lines: List[str]) -> List[str]:
    out = []
    for line in lines:
        if "while(" in line:
            continue        # while bodies handled with trip multipliers
        out.extend(_CALL_RE.findall(line))
    return out


def hlo_totals(hlo: str, trip_hints: Optional[List[int]] = None
               ) -> Dict[str, float]:
    """Whole-program collective bytes + matmul FLOPs.

    trip_hints[d] multiplies totals inside while loops at nesting depth d
    (0 = outermost).  Missing depths default to 1."""
    comps = _split_computations(hlo)
    if "__entry__" not in comps:
        return {}
    defs = parse_defs(hlo)
    hints = trip_hints or []

    def hint(depth: int) -> int:
        return hints[depth] if depth < len(hints) else 1

    stack: set = set()

    def walk(name: str, depth: int) -> Dict[str, float]:
        if name not in comps or name in stack:
            return {}
        stack.add(name)
        lines = comps[name]
        total = _totals_of(lines, defs)
        for callee in _callees(lines):
            for k, v in walk(callee, depth).items():
                total[k] = total.get(k, 0.0) + v
        for body in _while_bodies(lines):
            mult = hint(depth)
            for k, v in walk(body, depth + 1).items():
                total[k] = total.get(k, 0.0) + v * mult
        stack.discard(name)
        return total

    totals = walk("__entry__", 0)
    totals["total_operand_bytes"] = sum(
        v for k, v in totals.items() if k.startswith("op_"))
    totals["total_wire_bytes"] = sum(
        v for k, v in totals.items() if k.startswith("wire_"))
    return totals


# Backwards-compatible name (collective-only view).
def collective_totals(hlo: str, trip_hints: Optional[List[int]] = None
                      ) -> Dict[str, float]:
    return hlo_totals(hlo, trip_hints)
