"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the slot-based continuous-batching engine with random weights (or
a checkpoint) and drives a synthetic request stream — the inference-side
end-to-end driver.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import restore
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ref = {"params": params}
        tree, step, _ = restore(args.ckpt_dir, ref)
        params = tree["params"]
        print(f"restored checkpoint step {step}")

    eng = ServingEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new=args.max_new, temperature=args.temperature))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
