"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Default is the production front door: an :class:`AsyncLMServer` around the
request-level ``EngineCore``, driven by a Poisson arrival trace (``--rate``
req/s) of streaming clients with per-request sampling params
(``--temperature/--top-k/--top-p/--seed/--stop``), reporting sustained
req/s, TTFT p50/p99 and time-per-output-token.  ``--batch`` falls back to
the synchronous submit-all-then-drain driver; cache layouts the page pool
rejects (ring-buffer sliding windows wider than a page, SSM state) fall
back to the slot-contiguous ``ServingEngine`` (sync only — it cannot
abort, which the async server requires).
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.checkpoint import restore
from repro.configs import get_config
from repro.models import build_model
from repro.serving import (AsyncLMServer, EngineCore, Request,
                           SamplingParams, ServingEngine,
                           UnsupportedCacheLayout, start_metrics_server,
                           write_metrics_json)


def _parse_stop(spec: str):
    """``"5,9;12"`` → ((5, 9), (12,)): ';' splits sequences, ',' tokens."""
    if not spec:
        return ()
    return tuple(tuple(int(t) for t in s.split(",")) for s in spec.split(";"))


def _requests(args, cfg):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        sp = SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p,
            seed=(None if args.temperature <= 0 else args.seed + i),
            stop=_parse_stop(args.stop))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new=args.max_new, sampling=sp))
    return reqs


def _run_async(eng, args, cfg) -> None:
    reqs = _requests(args, cfg)
    rng = np.random.default_rng(1)
    # Poisson arrivals: exponential inter-arrival gaps at --rate req/s
    # (rate 0 → everyone arrives at t=0, the burst case).
    arrivals = (np.cumsum(rng.exponential(1.0 / args.rate, len(reqs)))
                if args.rate > 0 else np.zeros(len(reqs)))

    async def client(server, req, delay):
        await asyncio.sleep(delay)
        toks = []
        async for tok in server.generate(req):
            toks.append(tok)
        return toks

    async def main():
        server = AsyncLMServer(eng, max_waiting=args.max_waiting,
                               admission=args.admission)
        # /metrics + /metrics.json off this very loop (--metrics-port):
        # the scrape endpoint shares the process with the serve loop and
        # reads the same registry summary() reports from.
        exporter = None
        if args.metrics_port is not None:
            exporter = await start_metrics_server(server.obs.registry,
                                                  port=args.metrics_port)
            port = exporter.sockets[0].getsockname()[1]
            print(f"metrics: http://127.0.0.1:{port}/metrics")
        try:
            async with server:
                await asyncio.gather(*[
                    client(server, r, float(d))
                    for r, d in zip(reqs, arrivals)])
        finally:
            if exporter is not None:
                exporter.close()
                await exporter.wait_closed()
        return server.summary()

    t0 = time.perf_counter()
    s = asyncio.run(main())
    dt = time.perf_counter() - t0
    print(f"async serve loop: {s['requests']} requests / {s['tokens']} "
          f"tokens in {dt:.2f}s over {s['steps']} steps "
          f"(offered rate {args.rate or 'burst'} req/s)")
    print(f"  sustained {s['req_s']:.2f} req/s · TTFT p50 "
          f"{s['ttft_ms_p50']:.1f}ms p99 {s['ttft_ms_p99']:.1f}ms · "
          f"TPOT {s['tpot_ms']:.2f}ms")


def _run_batch(eng, args, cfg) -> None:
    for r in _requests(args, cfg):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"batch driver: served {len(done)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.tokens[:12]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", "--slots", dest="lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable shared-prefix KV reuse (radix cache + "
                         "copy-on-write page sharing)")
    ap.add_argument("--cache-pages", type=int, default=None,
                    help="cap on resident prefix-cache pages (default: "
                         "bounded only by the pool, reclaimed LRU-first)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-then-verify speculative decoding: an n-gram "
                         "prompt-lookup proposer drafts up to --spec-k "
                         "tokens per greedy decode lane, verified in the "
                         "same ragged step (greedy output is unchanged)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per lane per step")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed base (request i draws "
                         "from seed+i; streams are batch-invariant)")
    ap.add_argument("--stop", default="",
                    help="stop sequences as token ids: ',' joins tokens in "
                         "a sequence, ';' separates sequences (e.g. '5,9;12')")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate in req/s (0 = burst: all "
                         "requests arrive at t=0)")
    ap.add_argument("--max-waiting", type=int, default=64,
                    help="intake queue bound (admission backpressure)")
    ap.add_argument("--admission", choices=("wait", "reject"),
                    default="wait",
                    help="backpressure policy when intake is full")
    ap.add_argument("--batch", action="store_true",
                    help="synchronous submit-all-then-drain driver instead "
                         "of the async serve loop")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a JSON snapshot of the metrics registry "
                         "on exit")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve GET /metrics (Prometheus text) and "
                         "/metrics.json on 127.0.0.1:N off the serve "
                         "loop's own asyncio loop (0 = ephemeral port; "
                         "async driver only)")
    ap.add_argument("--profile-steps", type=int, default=None, metavar="N",
                    help="capture a jax.profiler trace window around the "
                         "next N engine steps")
    ap.add_argument("--profile-dir", default="/tmp/jax-trace",
                    help="jax.profiler trace output dir (--profile-steps)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ref = {"params": params}
        tree, step, _ = restore(args.ckpt_dir, ref)
        params = tree["params"]
        print(f"restored checkpoint step {step}")

    slot = False
    try:
        # ceil per lane: a --max-len request must always fit its worst case
        pages_per_lane = -(-args.max_len // args.page_size)
        eng = EngineCore(cfg, params, lanes=args.lanes,
                         page_size=args.page_size,
                         num_pages=args.lanes * pages_per_lane,
                         chunk_size=args.chunk_size, max_len=args.max_len,
                         prefix_cache=args.prefix_cache,
                         cache_pages=args.cache_pages,
                         speculative=args.speculative, spec_k=args.spec_k)
        print(f"engine: EngineCore (paged, chunk={args.chunk_size}, "
              f"prefix_cache={'on' if args.prefix_cache else 'off'}, "
              f"speculative="
              f"{f'k={args.spec_k}' if args.speculative else 'off'})")
    except UnsupportedCacheLayout as e:
        print(f"engine: ServingEngine (slot-contiguous, sync only) — {e}")
        eng = ServingEngine(cfg, params, slots=args.lanes,
                            max_len=args.max_len)
        slot = True

    if args.profile_steps and not slot:
        eng.obs.arm_profiler(args.profile_steps, args.profile_dir)
        print(f"profiler: tracing next {args.profile_steps} steps "
              f"into {args.profile_dir}")

    if args.batch or slot:
        _run_batch(eng, args, cfg)
    else:
        _run_async(eng, args, cfg)

    if args.metrics_json and not slot:
        write_metrics_json(eng.obs.registry, args.metrics_json)
        print(f"metrics snapshot: {args.metrics_json}")

    stats = getattr(eng, "prefix_stats", {})
    if stats:
        print(f"prefix cache: hit_rate {stats['hit_rate']:.3f} "
              f"({stats['hit_tokens']} of {stats['lookup_tokens']} known "
              f"tokens), {stats['cached_pages']} pages cached, "
              f"{stats['cow_copies']} CoW copies")
    spec = getattr(eng, "spec_stats", {})
    if spec:
        print(f"speculative: {spec['accepted_tokens']} of "
              f"{spec['drafted_tokens']} drafts accepted "
              f"(acceptance {spec['acceptance']:.3f}, "
              f"+{spec['accepted_per_spec_step']:.2f} tok per "
              f"drafting step over {spec['spec_steps']} steps)")


if __name__ == "__main__":
    main()
