"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the request-level ``EngineCore`` (continuous batching, chunked paged
prefill, preemption-by-eviction) with random weights (or a checkpoint) over
a synthetic request stream — the inference-side end-to-end driver.  Cache
layouts the page pool rejects (ring-buffer sliding windows wider than a
page, SSM state) fall back to the slot-contiguous ``ServingEngine``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import restore
from repro.configs import get_config
from repro.models import build_model
from repro.serving import (EngineCore, Request, ServingEngine,
                           UnsupportedCacheLayout)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", "--slots", dest="lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable shared-prefix KV reuse (radix cache + "
                         "copy-on-write page sharing)")
    ap.add_argument("--cache-pages", type=int, default=None,
                    help="cap on resident prefix-cache pages (default: "
                         "bounded only by the pool, reclaimed LRU-first)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-then-verify speculative decoding: an n-gram "
                         "prompt-lookup proposer drafts up to --spec-k "
                         "tokens per greedy decode lane, verified in the "
                         "same ragged step (greedy output is unchanged)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per lane per step")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ref = {"params": params}
        tree, step, _ = restore(args.ckpt_dir, ref)
        params = tree["params"]
        print(f"restored checkpoint step {step}")

    try:
        # ceil per lane: a --max-len request must always fit its worst case
        pages_per_lane = -(-args.max_len // args.page_size)
        eng = EngineCore(cfg, params, lanes=args.lanes,
                         page_size=args.page_size,
                         num_pages=args.lanes * pages_per_lane,
                         chunk_size=args.chunk_size, max_len=args.max_len,
                         prefix_cache=args.prefix_cache,
                         cache_pages=args.cache_pages,
                         speculative=args.speculative, spec_k=args.spec_k)
        print(f"engine: EngineCore (paged, chunk={args.chunk_size}, "
              f"prefix_cache={'on' if args.prefix_cache else 'off'}, "
              f"speculative="
              f"{f'k={args.spec_k}' if args.speculative else 'off'})")
    except UnsupportedCacheLayout as e:
        print(f"engine: ServingEngine (slot-contiguous) — {e}")
        eng = ServingEngine(cfg, params, slots=args.lanes,
                            max_len=args.max_len)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new=args.max_new, temperature=args.temperature))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    stats = getattr(eng, "prefix_stats", {})
    if stats:
        print(f"prefix cache: hit_rate {stats['hit_rate']:.3f} "
              f"({stats['hit_tokens']} of {stats['lookup_tokens']} known "
              f"tokens), {stats['cached_pages']} pages cached, "
              f"{stats['cow_copies']} CoW copies")
    spec = getattr(eng, "spec_stats", {})
    if spec:
        print(f"speculative: {spec['accepted_tokens']} of "
              f"{spec['drafted_tokens']} drafts accepted "
              f"(acceptance {spec['acceptance']:.3f}, "
              f"+{spec['accepted_per_spec_step']:.2f} tok per "
              f"drafting step over {spec['spec_steps']} steps)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
