"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real step
function (train / prefill / decode) against ShapeDtypeStruct inputs with
explicit in/out shardings, compiles, and records

    memory_analysis()   → per-device bytes (fits-in-HBM proof)
    cost_analysis()     → FLOPs / bytes for §Roofline
    HLO collectives     → collective bytes (launch/hlo_analysis.py)

into ``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
# The placeholder-device flag MUST precede any jax import (device count is
# locked at first init).  Do not move; do not set globally.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
os.environ.setdefault("REPRO_TARGET_TPU", "1")   # lower MXU-native bf16 dots

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ASSIGNED, cell_status, get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import hlo_totals
from repro.models import build_model, input_specs
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import (activation_sharding, batch_specs, cache_specs,
                            cache_specs_decode, param_specs)
from repro.parallel.ctx import maybe_shard

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# v5e hardware constants (per chip) — §Roofline.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
HBM_BYTES = 16 * 2 ** 30


def _ns(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_specs(pspecs: Any) -> Any:
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), m=pspecs, v=pspecs)


def build_cell(cfg: ModelConfig, kind: str, seq: int, batch: int,
               mesh: Mesh) -> Tuple[Any, tuple, dict]:
    """→ (jitted fn, arg SDS tuple, metadata)."""
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_sds, mesh)
    specs_in = input_specs(cfg, kind, seq, batch)

    if kind == "train":
        opt_cfg = AdamWConfig(moment_dtype="bfloat16")
        opt_sds = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg),
                                 params_sds)
        ospecs = _opt_specs(pspecs)
        bspecs = batch_specs(specs_in["batch"], mesh)
        # Production memory policy for the biggest models: microbatch the
        # step down to 1 row/chip and accumulate gradients in bf16; stream
        # the optimizer update over the stacked-period axis (DESIGN.md §4).
        # Tiered microbatching (production default; §Perf): ≥50B params →
        # 1 row/chip + bf16 accumulation; ≥2B → 4 microbatches; small → none.
        n_params = cfg.param_count()
        dp = mesh.size // mesh.shape["model"]
        if n_params > 50e9:
            micro = max(1, min(16, batch // dp))
            accum = "bfloat16"
        elif n_params > 2e9:
            micro = min(4, max(1, batch // dp))
            accum = "float32"
        else:
            micro, accum = 1, "float32"
        # hillclimb override (EXPERIMENTS.md §Perf): force a microbatch count
        env_micro = int(os.environ.get("REPRO_TRAIN_MICRO", "0"))
        if env_micro:
            micro = env_micro
            accum = os.environ.get("REPRO_TRAIN_ACCUM", accum)

        def train_step(params, opt_state, batch):
            from repro.optim import accumulated_grads
            loss, grads, _ = accumulated_grads(
                lambda p, b: model.loss(p, b), params, batch, micro,
                accum_dtype=accum)
            # NOTE: scan-streaming the optimizer over the period axis was
            # measured and REJECTED — it breaks donation aliasing (peak
            # 36.8 vs 20.4 GiB on grok; EXPERIMENTS.md §Perf).
            new_p, new_o, _ = adamw_update(grads, opt_state, params, opt_cfg)
            return new_p, new_o, loss

        fn = jax.jit(
            train_step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                          _ns(mesh, bspecs)),
            out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1))
        args = (params_sds, opt_sds, specs_in["batch"])

    elif kind == "prefill":
        bspecs = batch_specs(specs_in["batch"], mesh)
        cspecs = cache_specs(specs_in["caches"], mesh)

        def prefill_step(params, batch, caches):
            return model.prefill(params, batch, caches)

        fn = jax.jit(
            prefill_step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                          _ns(mesh, cspecs)),
            out_shardings=None,
            donate_argnums=(2,))
        args = (params_sds, specs_in["batch"], specs_in["caches"])

    elif kind == "decode":
        # sequence-sharded KV at decode: the paper's Fig-5 gather (§Perf)
        cspecs = cache_specs_decode(specs_in["state"], mesh)
        tok_spec = batch_specs({"t": specs_in["token"]}, mesh)["t"]

        def serve_step(params, token, state, index):
            return model.decode_step(params, token, state, index)

        fn = jax.jit(
            serve_step,
            in_shardings=(_ns(mesh, pspecs), NamedSharding(mesh, tok_spec),
                          _ns(mesh, cspecs), NamedSharding(mesh, P())),
            out_shardings=(None, _ns(mesh, cspecs)),
            donate_argnums=(2,))
        args = (params_sds, specs_in["token"], specs_in["state"],
                specs_in["index"])
    else:
        raise ValueError(kind)

    return fn, args, {"kind": kind}


def _trip_hints(cfg: ModelConfig, kind: str, seq: int) -> list:
    """While-loop trip multipliers by nesting depth (layer scan, then the
    longest plausible inner scan: KV-block stream or SSM chunk scan)."""
    from repro.models.lm import period_layout
    try:
        _, nper, _ = period_layout(cfg)
    except Exception:
        nper = max(cfg.num_layers, 1)
    nper = max(nper, 1)
    inner = max(seq // max(cfg.block_k, 1),
                seq // max(cfg.ssm_chunk, 1) if cfg.ssm_state else 0, 1)
    return [nper, inner]


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (fwd), N = active params."""
    n = cfg.active_param_count()
    d = batch * (seq if kind in ("train", "prefill") else 1)
    return (6.0 if kind == "train" else 2.0) * n * d


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR,
             kv_quant: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)
    seq, batch, kind = SHAPES[shape]
    mesh_name = ("multipod" if multi_pod else "singlepod")
    if kv_quant:
        mesh_name += "-kvq"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    fn, args, _ = build_cell(cfg, kind, seq, batch, mesh)
    with activation_sharding(mesh):
        lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    trips = _trip_hints(cfg, kind, seq)
    coll = hlo_totals(hlo, trips)
    raw = hlo_totals(hlo, None)

    # cost_analysis visits while bodies ONCE → undercounts a scan-over-layers
    # program by ~trip_count.  The HLO dot-FLOP count (loop-scaled) is the
    # primary compute figure; cost_analysis bytes are scaled by the same
    # loop factor (approximation: loop bodies dominate both).
    flops_dev = float(coll.pop("flops", 0.0))
    flops_raw = max(raw.get("flops", 0.0), 1.0)
    loop_factor = max(flops_dev / flops_raw, 1.0)
    cost_flops = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0)) * loop_factor
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "kind": kind,
        "seq": seq, "global_batch": batch, "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            # donated args alias outputs (train/prefill/decode all donate
            # their state), so live peak = max(args, outputs) + temps
            "peak_bytes": (max(getattr(mem, "argument_size_in_bytes", 0),
                               getattr(mem, "output_size_in_bytes", 0))
                           + getattr(mem, "temp_size_in_bytes", 0)),
            "hbm_limit": HBM_BYTES,
            "fits": (max(getattr(mem, "argument_size_in_bytes", 0),
                         getattr(mem, "output_size_in_bytes", 0))
                     + getattr(mem, "temp_size_in_bytes", 0)) <= HBM_BYTES,
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "xla_cost_flops_raw": cost_flops,
                 "loop_factor": loop_factor},
        "collectives": coll,
        "roofline": {},
    }
    # §Roofline terms (cost_analysis is per-device post-partitioning).
    # bytes_dev is op-level (unfused) byte counting — an UPPER bound on HBM
    # traffic; the live-buffer peak is the fused lower bound.  True traffic
    # sits between; both are recorded.
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    peak_live = (max(getattr(mem, "argument_size_in_bytes", 0),
                     getattr(mem, "output_size_in_bytes", 0))
                 + getattr(mem, "temp_size_in_bytes", 0))
    t_memory_lb = peak_live / HBM_BW
    t_coll = coll.get("total_operand_bytes", 0.0) / chips / ICI_BW
    t_wire = coll.get("total_wire_bytes", 0.0) / chips / ICI_BW
    mf = model_flops(cfg, kind, seq, batch)
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    result["roofline"] = {
        "compute_s": t_compute, "memory_s": t_memory,
        "memory_lb_s": t_memory_lb,
        "collective_s": t_coll, "collective_wire_s": t_wire,
        "dominant": dom,
        "model_flops": mf,
        "model_flops_per_device": mf / chips,
        "useful_flop_ratio": (mf / chips / flops_dev) if flops_dev else None,
    }
    _write(out_dir, arch, shape, mesh_name, result)
    return result


def _write(out_dir: str, arch: str, shape: str, mesh_name: str,
           result: Dict[str, Any]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)


def cell_path(out_dir: str, arch: str, shape: str, mesh_name: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV caches (hillclimb arm; writes *-kvq cells)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        mesh_name = ("multipod" if mp else "singlepod") + (
            "-kvq" if args.kv_quant else "")
        status = cell_status(a, s)
        path = cell_path(args.out, a, s, mesh_name)
        if status.startswith("skip"):
            _write(args.out, a, s, mesh_name,
                   {"arch": a, "shape": s, "mesh": mesh_name,
                    "status": status})
            print(f"[skip] {a} × {s} × {mesh_name}: {status}")
            continue
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    print(f"[cached] {a} × {s} × {mesh_name}")
                    continue
        try:
            r = run_cell(a, s, mp, args.out,
                         kv_quant=args.kv_quant)
            peak = r["memory"]["peak_bytes"] or 0
            fits = "" if r["memory"]["fits"] else "  ** OVER HBM **"
            print(f"[ok] {a} × {s} × {mesh_name}: "
                  f"peak {peak/2**30:.2f} GiB/dev, "
                  f"dominant={r['roofline']['dominant']}, "
                  f"compile {r['compile_s']:.0f}s{fits}", flush=True)
        except Exception as e:
            failures += 1
            _write(args.out, a, s, mesh_name,
                   {"arch": a, "shape": s, "mesh": mesh_name,
                    "status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()})
            print(f"[FAIL] {a} × {s} × {mesh_name}: {e!r}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
