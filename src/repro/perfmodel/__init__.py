from repro.perfmodel.hardware import DEFAULT_HW, GPU, GpuAnchors, Hardware
from repro.perfmodel.model import (BERT_BASE, BERT_LARGE, bert_ops,
                                   encoder_layer_energy_j,
                                   encoder_layer_latency_s, end_to_end_tops,
                                   end_to_end_latency_s, headline_numbers,
                                   softmax_cores, softmax_energy_j,
                                   softmax_fraction, softmax_latency_s,
                                   tops_per_watt)

__all__ = ["Hardware", "GpuAnchors", "DEFAULT_HW", "GPU",
           "softmax_latency_s", "softmax_energy_j", "softmax_cores",
           "encoder_layer_latency_s", "encoder_layer_energy_j",
           "softmax_fraction", "end_to_end_tops", "end_to_end_latency_s",
           "tops_per_watt", "bert_ops", "headline_numbers",
           "BERT_BASE", "BERT_LARGE"]
