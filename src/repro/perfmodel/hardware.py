"""Paper Table II hardware characteristics + calibrated cycle constants.

Physical constants are copied from HASTILY Table II (32nm-scaled, 1 GHz
assumed — PUMA's clock).  Cycle-count constants that the paper's
cycle-level simulator encodes but the text does not print are CALIBRATED
against the paper's own anchor measurements (Fig. 7: softmax 22.13 µs /
6 µs / 1.36 µs at l=8192, W=16; Fig. 12: BERT-Base 158 TOPS) and then used
to *predict* every other claim — the validation tests in
``tests/test_perfmodel.py`` check the predictions, not the anchors.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    # ---- Table II (per node) ----
    clock_hz: float = 1e9
    tiles: int = 128
    cores_per_tile: int = 8
    uclms_per_core: int = 16
    arrays_per_uclm: int = 8          # 8 crossbars hold the 8 weight bits
    array_rows: int = 64
    array_cols: int = 64
    alu_width: int = 64               # VFU lanes (Fig 7 sweeps 16/32/64)

    # power (W)
    p_tile: float = 1.14
    p_core: float = 0.1403
    p_vfu: float = 1.7e-3
    p_rf: float = 1.14e-3
    p_uclm_mm: float = 22.38e-3       # MVM mode (incl. ADC/S&A/S&H)
    p_uclm_lt: float = 0.518e-3       # lookup mode
    p_gb: float = 25.35e-3
    p_bus: float = 6e-3

    # area (mm²; 32nm)
    area_total: float = 330.0

    # ---- calibrated cycle constants (see module docstring) ----
    c_exp_sw: float = 36.2            # software MacLaurin exp, cycles/elem
    c_div: float = 4.0                # reciprocal-multiply, cycles/elem
    c_vfu_misc: float = 4.2           # n/d decompose + bit-shift (LUT path)
    c_lookup: float = 4.0             # SRAM LT op latency (paper §III-A2)
    c_comm: float = 118.0             # tree-gather level (store+load, shmem)
    t_mvm_ns: float = 184.0           # crossbar MVM pipeline-stage latency

    # energy constants — calibrated to Fig 8 (≈1.6× PUMA/HASTILY softmax
    # ratio) and Fig 13 (≈8 TOPS/W, model-size invariant)
    e_vfu_op: float = 2.66e-14        # p_vfu / (alu_width · clock), J/elem
    e_rf_word: float = 1.78e-14       # p_rf / (alu_width · clock), J/word
    e_exp_sw_extra: float = 1.7e-13   # software-exp surcharge vs LUT, J/elem
    e_comm_word: float = 1.0e-12      # shared-mem word during tree gather
    e_op: float = 0.115e-12           # J per (int8 MAC-derived) op, end2end
    p_idle: float = 2.0               # W — GB + bus + leakage floor

    # ---- derived ----
    @property
    def cores(self) -> int:
        return self.tiles * self.cores_per_tile

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.clock_hz

    @property
    def arrays_per_core(self) -> int:
        return self.uclms_per_core * self.arrays_per_uclm

    @property
    def macs_per_core_mvm(self) -> int:
        """int8 MACs per crossbar op per core (8 arrays = 1 weight tile)."""
        return self.uclms_per_core * self.array_rows * self.array_cols

    @property
    def core_weight_capacity(self) -> int:
        """int8 weights resident per core."""
        return self.uclms_per_core * self.array_rows * self.array_cols


# The paper's measured GPU anchors (published inputs, not our model):
# Nvidia A40, bitsandbytes INT8, dynamic power (idle subtracted).
@dataclasses.dataclass(frozen=True)
class GpuAnchors:
    tops_bert_base_b1: float = 19.0      # Fig 12
    tops_peak_claim: float = 0.0
    tops_w_b1: float = 0.3               # Fig 13
    tops_w_b4: float = 0.9
    die_mm2: float = 628.4


DEFAULT_HW = Hardware()
GPU = GpuAnchors()
