"""Analytical latency/energy model of HASTILY vs PUMA vs A40 (paper Figs 7-13).

Reproduces the paper's cycle-level-simulator evaluation as closed-form
structural formulas over the Table II hardware description.  Soft constants
the paper doesn't print are calibrated on the Fig. 7 anchors (see
``hardware.py``); everything else is *predicted* and checked against the
paper's claims in tests/test_perfmodel.py:

  Fig 7   softmax latency (PUMA / UCLM / UCLM+multicore) × l × ALU width
  Fig 8   softmax energy, PUMA ≈ 1.6× HASTILY for l > 1024
  Fig 9   encoder-layer latency (softmax accel ±, fine-grained pipelining ±)
  Fig 10  runtime share of softmax (PUMA 38% → 13% at l=1024)
  Fig 12  end-to-end TOPS (BERT-Base 158, BERT-Large 263; PUMA 26, GPU 19)
  Fig 13  TOPS/W (HASTILY ≈ 8 regardless of model/batch)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.perfmodel.hardware import DEFAULT_HW, GPU, Hardware


# --------------------------------------------------------------------------
# softmax (per vector of length l) — Fig 7 / Fig 8
# --------------------------------------------------------------------------

def softmax_cores(hw: Hardware, l: int) -> int:
    """Cores the multicore softmax spreads one l-vector over.

    K^T is mapped 64-column tiles per UCLM → 16·64 = 1024 columns per core's
    UCLMs live in 1 core, but the VFU work is spread over the (two-tile)
    neighbourhood: 1 core per 512 columns, ≤ 16 (paper §III-B2)."""
    return max(1, min(16, l // 512))


def softmax_latency_s(hw: Hardware, l: int, mode: str,
                      alu_width: int | None = None) -> float:
    """mode ∈ {puma, uclm, multicore, hastily}.

    ``hastily`` = min(uclm, multicore): the compiler schedules whichever is
    faster (multicore only pays off once the tree gather amortises —
    matching Fig 7's "no difference at smaller l")."""
    w = alu_width or hw.alu_width
    cyc = hw.cycle_s
    if mode == "puma":
        # max, sub, reduce on VFU + software exp + reciprocal-multiply
        per_elem = 3 + hw.c_exp_sw + hw.c_div
        return (l / w) * per_elem * cyc
    if mode == "uclm":
        lookup = math.ceil(l / hw.arrays_per_core) * hw.c_lookup
        per_elem = 3 + hw.c_vfu_misc + hw.c_div
        return ((l / w) * per_elem + lookup) * cyc
    if mode == "multicore":
        n = softmax_cores(hw, l)
        lc = l / n
        lookup = math.ceil(lc / hw.arrays_per_core) * hw.c_lookup
        per_elem = 3 + hw.c_vfu_misc + hw.c_div
        tree = 2 * math.log2(max(n, 2)) * hw.c_comm if n > 1 else 0.0
        return ((lc / w) * per_elem + lookup + tree) * cyc
    if mode == "hastily":
        return min(softmax_latency_s(hw, l, "uclm", w),
                   softmax_latency_s(hw, l, "multicore", w))
    raise ValueError(mode)


def softmax_energy_j(hw: Hardware, l: int, mode: str) -> float:
    """Per-vector softmax energy (Fig 8 trends).

    Common base: 5 VFU element ops + 2 RF word accesses; PUMA adds the
    software-exp surcharge (calibrated to the paper's ≈1.6× ratio); the LUT
    path adds the (small) SRAM-LT energy; multicore adds the tree-gather
    shared-memory words — small, matching Fig 8's "small difference between
    UCLM only and multi-core"."""
    base = 5 * hw.e_vfu_op + 2 * hw.e_rf_word
    if mode == "puma":
        return l * (base + hw.e_exp_sw_extra)
    e_lut = hw.p_uclm_lt * (hw.c_lookup * hw.cycle_s) / hw.array_cols
    e = l * (base + e_lut)
    if mode in ("multicore", "hastily"):
        n = softmax_cores(hw, l)
        if n > 1 and (mode == "multicore"
                      or softmax_latency_s(hw, l, "multicore")
                      < softmax_latency_s(hw, l, "uclm")):
            e += 2 * math.log2(n) * n * hw.e_comm_word
    return e


# --------------------------------------------------------------------------
# encoder layer — Fig 9 / 10 / 11
# --------------------------------------------------------------------------

def _layer_op_counts(l: int, d: int, d_ff: int | None = None,
                     heads: int | None = None) -> Dict[str, float]:
    """MAC·2 op counts per encoder layer (paper's TOPS convention)."""
    d_ff = d_ff or 4 * d
    heads = heads or d // 64
    static = l * (4 * d * d + 2 * d * d_ff)          # QKVO + FF1 + FF2
    dynamic = 2 * l * l * d                           # QK^T + SV
    return {"static": 2 * static, "dynamic": 2 * dynamic,
            "total": 2 * (static + dynamic)}


def mvm_stage_s(hw: Hardware) -> float:
    return hw.t_mvm_ns * 1e-9


def encoder_layer_latency_s(hw: Hardware, l: int, d: int, *,
                            softmax_mode: str = "hastily",
                            pipelined: str = "fine",
                            d_ff: int | None = None) -> float:
    """One encoder layer (attention + FFN), Fig 9 model.

    pipelined ∈ {"none", "coarse", "fine"}:
      none    — the six MatMul blocks run back-to-back, l vectors each,
                plus l softmax vectors (Fig 10's un-pipelined breakdown);
      coarse  — PUMA's block dataflow: MatMuls overlap (fill+drain ≈ 2·l
                stages) but softmax still serialises on the VFU;
      fine    — HASTILY §IV: everything overlaps; the softmax only shows
                when slower than one crossbar stage.
    """
    t_mvm = mvm_stage_s(hw)
    t_sm = softmax_latency_s(hw, l, softmax_mode)
    if pipelined == "none":
        return 6 * l * t_mvm + l * t_sm
    if pipelined == "coarse":
        return 2 * l * t_mvm + l * t_sm
    return 2 * l * max(t_mvm, t_sm)


def softmax_fraction(hw: Hardware, l: int, d: int, mode: str) -> float:
    """Fig 10: softmax share of un-pipelined layer runtime."""
    t_total = encoder_layer_latency_s(hw, l, d, softmax_mode=mode,
                                      pipelined="none")
    t_sm = l * softmax_latency_s(hw, l, mode)
    return t_sm / t_total


def encoder_layer_energy_j(hw: Hardware, l: int, d: int, *,
                           softmax_mode: str = "hastily",
                           d_ff: int | None = None) -> float:
    """Fig 11: dominated by crossbar MVM (ADC) energy — per-op count.

    The paper notes PUMA-vs-HASTILY layer energy is "negligible" apart —
    both are e_op · ops; only the softmax term differs."""
    ops = _layer_op_counts(l, d, d_ff)
    e_mvm = ops["total"] * hw.e_op
    e_sm = l * softmax_energy_j(hw, l, softmax_mode)
    return e_mvm + e_sm


# --------------------------------------------------------------------------
# end-to-end — Fig 12 / 13
# --------------------------------------------------------------------------

def bert_ops(n_layers: int, l: int, d: int, d_ff: int) -> float:
    per = _layer_op_counts(l, d, d_ff)["total"]
    return n_layers * per


def end_to_end_latency_s(hw: Hardware, n_layers: int, l: int, d: int,
                         d_ff: int, *, pipelined: str = "fine",
                         softmax_mode: str = "hastily",
                         batch: int = 1) -> float:
    """HASTILY pipeline: N layers drain in (N+1)·l MVM-stage times (§IV).

    Fine-grained pipelining holds ≤2 batches' weights resident (paper §VI-C);
    beyond that, batches serialise.  PUMA holds 4 batches (coarse mode)."""
    if pipelined == "fine":
        t_sm = softmax_latency_s(hw, l, softmax_mode)
        stage = max(mvm_stage_s(hw), t_sm)
        per_pass = (n_layers + 1) * l * stage
        return math.ceil(batch / 2) * per_pass
    per_layer = encoder_layer_latency_s(hw, l, d, softmax_mode=softmax_mode,
                                        pipelined=pipelined, d_ff=d_ff)
    return math.ceil(batch / 4) * n_layers * per_layer


def end_to_end_tops(hw: Hardware, n_layers: int, l: int, d: int, d_ff: int,
                    *, pipelined: str = "fine",
                    softmax_mode: str = "hastily",
                    batch: int = 1) -> float:
    ops = batch * bert_ops(n_layers, l, d, d_ff)
    t = end_to_end_latency_s(hw, n_layers, l, d, d_ff, pipelined=pipelined,
                             softmax_mode=softmax_mode, batch=batch)
    return ops / t / 1e12


def node_power_w(hw: Hardware, tops: float) -> float:
    """P = idle floor + e_op-proportional dynamic power (Fig 13's
    model-size-invariant TOPS/W falls out of this form)."""
    return hw.p_idle + tops * 1e12 * hw.e_op


def tops_per_watt(hw: Hardware, n_layers: int, l: int, d: int, d_ff: int,
                  *, batch: int = 1) -> float:
    t = end_to_end_tops(hw, n_layers, l, d, d_ff, batch=batch)
    return t / node_power_w(hw, t)


# --------------------------------------------------------------------------
# kernel roofline: q-block-tiled varlen paged attention
# --------------------------------------------------------------------------
#
# The serving-kernel analogue of the figures above: instead of crossbar
# stages, a bytes-moved/FLOPs roofline over the page-walk grid that
# ``kernels/autotune.py`` scores tile candidates against.  One varlen step
# is a set of lane segments (n_new tokens landing on kv_len live rows);
# tiling with q-blocks of Bq rows turns "read each page once per token"
# into "once per block" — the model counts exactly that.

@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """Roofline constants of the machine running the *serving kernels*.

    Not Table II — the jnp scan / Pallas kernel run on a host CPU or a TPU,
    and the tuner needs their balance point, not HASTILY's.  Numbers are
    order-of-magnitude (a tile choice flips on ratios, not absolutes).
    """
    name: str
    mem_bw_gbs: float        # sustained bytes/s feeding the kernel
    flops: float             # peak f32 FLOP/s
    dispatch_ns: float       # fixed cost per page-walk grid step
    dequant_page_ns: float   # extra per-page cost of page-granular dequant


PLATFORMS: Dict[str, PlatformSpec] = {
    # host CPU running the jnp page-block scan (XLA:CPU, ~1 socket)
    "cpu": PlatformSpec("cpu", mem_bw_gbs=40.0, flops=2e11,
                        dispatch_ns=400.0, dequant_page_ns=200.0),
    # one TPU core running the Pallas scalar-prefetch kernel; per-page
    # dequant is free there (the kernel walks one page per step anyway)
    "tpu": PlatformSpec("tpu", mem_bw_gbs=1.2e3, flops=2e14,
                        dispatch_ns=120.0, dequant_page_ns=0.0),
}


def platform_spec(name: str | None = None) -> PlatformSpec:
    return PLATFORMS.get(name or "", PLATFORMS["cpu"])


def varlen_attention_traffic(segments, *, block_q: int, block_pages: int,
                             page_size: int, hq: int, hkv: int, head_dim: int,
                             kv_bytes: int = 4,
                             scaled: bool = False) -> Dict[str, float]:
    """Bytes moved / FLOPs / grid steps of one tiled varlen step.

    ``segments``: iterable of ``(n_new, kv_len)`` lane chunks (kv_len counts
    the new rows).  ``block_q = 1`` is the untiled batch = T dataflow.  KV
    bytes dominate: every q-block walks its lane's live pages, so pages are
    read ``ceil(n/Bq)`` times per lane instead of ``n`` — the tiling win the
    autotuner is shopping for.  ``scaled`` adds the int8 dequant-scale
    planes (4 bytes/row alongside ``kv_bytes``/elem rows).
    """
    bq = max(1, int(block_q))
    bp = max(1, int(block_pages))
    row_bytes = 2 * head_dim * kv_bytes * hkv        # K + V, all kv heads
    if scaled:
        row_bytes += 2 * 4 * hkv                     # k_scale + v_scale rows
    bytes_kv = bytes_q = flops = steps = pages = 0.0
    for n_new, kv_len in segments:
        n_new = int(n_new)
        kv_len = int(kv_len)
        if n_new <= 0:
            continue
        nb = -(-n_new // bq)
        for j in range(nb):
            rows = min(bq, n_new - j * bq)
            kv_blk = kv_len - n_new + j * bq + rows  # block's causal horizon
            p_live = -(-kv_blk // page_size)
            pages += p_live
            bytes_kv += p_live * page_size * row_bytes
            bytes_q += 2 * rows * hq * head_dim * 4  # q read + out write
            flops += 4.0 * rows * (p_live * page_size) * hq * head_dim
            steps += -(-p_live // bp)
    return {"bytes_kv": bytes_kv, "bytes_q": bytes_q, "flops": flops,
            "grid_steps": steps, "pages_read": pages,
            "bytes_total": bytes_kv + bytes_q}


def varlen_attention_roofline(spec: PlatformSpec, traffic: Dict[str, float],
                              *, block_pages: int = 1,
                              dequant: str = "block") -> float:
    """Predicted step seconds: max(bytes/BW, flops/peak) + grid overheads."""
    t_mem = traffic["bytes_total"] / (spec.mem_bw_gbs * 1e9)
    t_cmp = traffic["flops"] / spec.flops
    t_grid = traffic["grid_steps"] * spec.dispatch_ns * 1e-9
    if dequant == "page" and block_pages > 1:
        t_grid += traffic["pages_read"] * spec.dequant_page_ns * 1e-9
    return max(t_mem, t_cmp) + t_grid


# --------------------------------------------------------------------------
# headline claim summary (used by benchmarks + tests)
# --------------------------------------------------------------------------

BERT_BASE = dict(n_layers=12, d=768, d_ff=3072, heads=12)
BERT_LARGE = dict(n_layers=24, d=1024, d_ff=4096, heads=16)


def headline_numbers(hw: Hardware = DEFAULT_HW) -> Dict[str, float]:
    base = dict(l=512)
    out = {
        "softmax_puma_8192_w16_us":
            softmax_latency_s(hw, 8192, "puma", 16) * 1e6,
        "softmax_uclm_8192_w16_us":
            softmax_latency_s(hw, 8192, "uclm", 16) * 1e6,
        "softmax_multicore_8192_w16_us":
            softmax_latency_s(hw, 8192, "multicore", 16) * 1e6,
        "softmax_w64_gain_pct":
            100 * (1 - softmax_latency_s(hw, 8192, "multicore", 64)
                   / softmax_latency_s(hw, 8192, "multicore", 16)),
        "softmax_energy_ratio_puma_4096":
            softmax_energy_j(hw, 4096, "puma")
            / softmax_energy_j(hw, 4096, "multicore"),
        "tops_bert_base": end_to_end_tops(
            hw, BERT_BASE["n_layers"], 512, BERT_BASE["d"],
            BERT_BASE["d_ff"], batch=2),
        "tops_bert_large": end_to_end_tops(
            hw, BERT_LARGE["n_layers"], 512, BERT_LARGE["d"],
            BERT_LARGE["d_ff"], batch=2),
        "tops_puma_bert_base": end_to_end_tops(
            hw, BERT_BASE["n_layers"], 512, BERT_BASE["d"],
            BERT_BASE["d_ff"], pipelined="coarse", softmax_mode="puma",
            batch=1),
        "tops_w_hastily": tops_per_watt(
            hw, BERT_BASE["n_layers"], 512, BERT_BASE["d"],
            BERT_BASE["d_ff"], batch=2),
        "gpu_tops_bert_base": GPU.tops_bert_base_b1,
        "softmax_frac_puma_1024":
            softmax_fraction(hw, 1024, 768, "puma"),
        "softmax_frac_hastily_1024":
            softmax_fraction(hw, 1024, 768, "hastily"),
    }
    out["speedup_tops_vs_gpu_base"] = (out["tops_bert_base"]
                                       / GPU.tops_bert_base_b1)
    out["tops_w_vs_gpu_b1"] = out["tops_w_hastily"] / GPU.tops_w_b1
    out["tops_w_vs_gpu_b4"] = out["tops_w_hastily"] / GPU.tops_w_b4
    return out
