"""gemma3-12b — dense LM, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  48L, d_model=3840, 16H (GQA kv=8),
head_dim=256, d_ff=15360, vocab=262144.  Five local (window 1024, rope 10k)
layers per one global (rope 1M) layer; QK-norm instead of logit softcap.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="[hf:google/gemma-3-1b-pt; unverified]",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    qk_norm=True,
    mlp_gated=True,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    post_block_norm=True,
    tie_embeddings=True,
)
