"""starcoder2-3b — dense code LM, GQA + RoPE.

[arXiv:2402.19173; hf]  30L, d_model=3072, 24H (GQA kv=2), d_ff=12288,
vocab=49152.  LayerNorm, non-gated GELU MLP, attention bias — per the
StarCoder2 reference implementation.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="[arXiv:2402.19173; hf]",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_gated=False,
    act="gelu",
    norm="layernorm",
    attn_bias=True,
    rope_theta=999_999.4,
    tie_embeddings=True,
)
