"""BERT-Base / BERT-Large — the paper's own benchmark models (Table III).

Encoder-only, learned positions, post-LN, GELU, MHA.  These drive the
paper-figure benchmarks (Figs 7-13) and the faithful-reproduction arm of
EXPERIMENTS.md.  Encoder-only → no decode shapes.
"""
from repro.configs.base import ModelConfig

BERT_BASE = ModelConfig(
    name="bert-base",
    family="bert",
    source="[paper Table III]",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30522,
    mlp_gated=False,
    act="gelu",
    norm="layernorm",
    postnorm=True,
    pos_embedding="learned",
    max_position=8192,
    attn_bias=True,
    tie_embeddings=True,
)

BERT_LARGE = BERT_BASE.replace(
    name="bert-large",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
)

CONFIG = BERT_BASE
