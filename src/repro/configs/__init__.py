"""Config registry: ``get_config(name)`` / ``--arch <id>``.

The 10 assigned architectures (each with its own input-shape set) plus the
paper's own BERT models.  Shape cells are defined in ``SHAPES`` and the
applicability matrix in ``CELLS`` (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, reduced
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.internvl2_1b import CONFIG as _internvl
from repro.configs.zamba2_1p2b import CONFIG as _zamba
from repro.configs.starcoder2_3b import CONFIG as _starcoder
from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.bert import BERT_BASE, BERT_LARGE

_REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in [
    _seamless, _granite, _grok, _falcon, _internvl,
    _zamba, _starcoder, _gemma2, _deepseek, _gemma3,
    BERT_BASE, BERT_LARGE,
]}

ASSIGNED: Tuple[str, ...] = (
    "seamless-m4t-large-v2", "granite-moe-3b-a800m", "grok-1-314b",
    "falcon-mamba-7b", "internvl2-1b", "zamba2-1.2b", "starcoder2-3b",
    "gemma2-9b", "deepseek-7b", "gemma3-12b",
)

# (seq_len, global_batch, step kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (SSM / hybrid / local-window);
# skipped cells carry the reason string (recorded in EXPERIMENTS.md).
_LONG_OK = {"falcon-mamba-7b", "zamba2-1.2b", "gemma2-9b", "gemma3-12b"}


def cell_status(arch: str, shape: str) -> str:
    """'run' or 'skip:<reason>' for an (arch × shape) cell."""
    if shape == "long_500k" and arch not in _LONG_OK:
        return "skip:pure full-attention arch — 500k context is quadratic (DESIGN.md)"
    return "run"


def all_cells() -> List[Tuple[str, str, str]]:
    return [(a, s, cell_status(a, s)) for a in ASSIGNED for s in SHAPES]


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    return sorted(_REGISTRY)


__all__ = ["ModelConfig", "reduced", "get_config", "list_configs",
           "ASSIGNED", "SHAPES", "cell_status", "all_cells",
           "BERT_BASE", "BERT_LARGE"]
