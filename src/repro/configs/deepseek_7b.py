"""deepseek-7b — dense llama-architecture LM.

[arXiv:2401.02954; hf]  30L, d_model=4096, 32H (MHA: kv=32), d_ff=11008,
vocab=102400.  RMSNorm, SiLU-gated MLP, RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    source="[arXiv:2401.02954; hf]",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    mlp_gated=True,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
)
