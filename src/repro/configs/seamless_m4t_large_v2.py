"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf]  24L per stack, d_model=1024, 16 heads (MHA: kv=16),
d_ff=8192, vocab=256206.  The speech frontend (w2v-BERT conformer feature
extractor) is a STUB per the assignment: ``input_specs()`` provides precomputed
frame embeddings of shape (B, L_src, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="[arXiv:2308.11596; hf]",
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_gated=False,
    act="gelu",
    norm="layernorm",
    pos_embedding="rope",
    tie_embeddings=True,
    frontend="audio",
    frontend_len=256,
)
