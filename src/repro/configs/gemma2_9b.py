"""gemma2-9b — dense LM with alternating local:global attention + logit softcaps.

[arXiv:2408.00118; hf]  42L, d_model=3584, 16H (GQA kv=8), head_dim=256,
d_ff=14336, vocab=256000.  Alternating (local window-4096, global) layers,
attention-logit softcap 50, final-logit softcap 30, GeGLU MLP, embedding scaled
by sqrt(d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="[arXiv:2408.00118; hf]",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_gated=True,
    act="gelu",
    norm="rmsnorm",
    embed_scale=True,
    post_block_norm=True,
    attn_scale=0.0625,       # gemma2-9b query_pre_attn_scalar=256 → 1/sqrt(256)
    tie_embeddings=True,
)
