"""zamba2-1.2b — Mamba-2 backbone with shared attention blocks (hybrid).

[arXiv:2411.15242; hf]  38 Mamba-2 blocks, d_model=2048, ssm_state=64; one
*shared* transformer block (32H MHA kv=32, d_ff=8192) interleaved every
``hybrid_period`` Mamba blocks (weights reused at every invocation — Zamba2's
parameter-sharing trick).  The HASTILY softmax technique applies to the shared
attention blocks; the Mamba-2 chunked scan is attention-free.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    num_layers=38,               # mamba2 blocks
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_variant="mamba2",
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_groups=1,
    hybrid_period=6,
    mlp_gated=True,
    act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
)
