"""internvl2-1b — InternViT + Qwen2-0.5B LM backbone.

[arXiv:2404.16821; hf]  LM trunk: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864,
vocab=151655.  The InternViT vision frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (B, n_patches, d_model)
prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="[arXiv:2404.16821; hf]",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    mlp_gated=True,
    act="silu",
    norm="rmsnorm",
    attn_bias=True,          # Qwen2 uses QKV bias
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend="vision",
    frontend_len=256,
)
