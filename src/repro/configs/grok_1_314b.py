"""grok-1-314b — xAI Grok-1 (8 experts, top-2).

[hf:xai-org/grok-1; unverified]  64L, d_model=6144, 48H (GQA kv=8),
d_ff=32768 per expert, vocab=131072, 8 experts top-2.  Grok uses gelu-gated
experts and attention-logit soft-capping (30.0).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    source="[hf:xai-org/grok-1; unverified]",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    mlp_gated=True,
    act="gelu",
    norm="rmsnorm",
    attn_softcap=30.0,
    tie_embeddings=True,
)
