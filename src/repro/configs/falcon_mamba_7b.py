"""falcon-mamba-7b — attention-free Mamba-1 LM.

[arXiv:2410.05355; unverified]  64L, d_model=4096, d_inner=8192 (expand 2),
ssm_state=16, conv 4, dt_rank=256, vocab=65024.  No attention anywhere → the
HASTILY softmax technique is inapplicable to the mixer (see DESIGN.md
§Arch-applicability); the SSM recurrence is already an O(l)-memory streaming
pipeline.  LUT-exp still serves the final vocab softmax.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="[arXiv:2410.05355; unverified]",
    num_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65024,
    ssm_variant="mamba1",
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    norm="rmsnorm",
    pos_embedding="none",
    tie_embeddings=False,
)
