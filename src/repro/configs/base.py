"""Model / run configuration.

One frozen dataclass covers all assigned architecture families; each family reads
the fields it needs.  ``reduced()`` derives the CPU smoke-test variant of any
config (same family/topology, tiny widths) as required by the assignment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # --- identity ---
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | bert
    source: str = ""                 # provenance note ([arXiv/hf; tier])

    # --- trunk dimensions ---
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention behaviour ---
    rope_theta: float = 10_000.0
    local_rope_theta: Optional[float] = None   # gemma3: local layers use 10k
    window: Optional[int] = None               # sliding-window size (local layers)
    layer_pattern: Tuple[str, ...] = ()        # cycled over layers, e.g. ("local","global")
    attn_softcap: Optional[float] = None       # gemma2 logit soft-capping
    final_softcap: Optional[float] = None      # gemma2 final-logit soft-capping
    qk_norm: bool = False                      # gemma3
    attn_bias: bool = False                    # starcoder2 / bert
    attn_scale: Optional[float] = None         # default 1/sqrt(head_dim)
    post_block_norm: bool = False              # gemma2/3: extra post-attn/mlp norms

    # --- mlp / norms / embeddings ---
    mlp_gated: bool = True
    act: str = "silu"                          # silu | gelu
    norm: str = "rmsnorm"                      # rmsnorm | layernorm
    postnorm: bool = False                     # BERT-style post-LN
    pos_embedding: str = "rope"                # rope | learned | none
    tie_embeddings: bool = True
    embed_scale: bool = False                  # gemma: scale embeddings by sqrt(d)
    max_position: int = 1 << 20                # learned-pos table size cap

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group: int = 512               # tokens per dispatch group (GShard G)

    # --- SSM ---
    ssm_state: int = 0
    ssm_variant: str = ""                      # mamba1 | mamba2
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64                     # mamba2
    ssm_groups: int = 1                        # mamba2 B/C groups
    ssm_chunk: int = 128                       # chunked-scan length
    ssm_dt_rank: int = 0                       # mamba1 (0 → d_model//16)
    hybrid_period: int = 0                     # zamba2: shared attn every N blocks

    # --- encoder-decoder ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = ""                         # audio | vision | ""
    frontend_len: int = 256                    # patches / audio frames in the prefix

    # --- numerics & HASTILY technique toggles ---
    dtype: str = "bfloat16"
    # Attention backend (core/attention_api registry): "auto" resolves
    # per-call from device platform and call shape; or pin one of the
    # registered names ("jnp" | "pallas" | "ring" | "naive" | ...).
    attn_backend: str = "auto"
    # Legacy selector, honoured when attn_backend == "auto":
    # streaming (HASTILY) | naive (baseline) | pallas (kernel fwd)
    attn_impl: str = "streaming"
    exp_mode: str = "lut"                      # lut | lut0 | exact
    block_k: int = 512
    use_int8: bool = False
    kv_quant: bool = False                     # int8 KV caches (serving)
    remat: bool = True
    scan_layers: bool = True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived ----
    @property
    def d_head(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Per-layer kinds, cycled.  Defaults by family."""
        if self.layer_pattern:
            return self.layer_pattern
        if self.family in ("ssm",):
            return ("mamba",)
        return ("global",)

    def layer_kinds(self, n: Optional[int] = None) -> Tuple[str, ...]:
        n = n or self.num_layers
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(n))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk), for 6ND roofline math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        dh, hq, hkv = self.d_head, self.num_heads, self.num_kv_heads
        attn = d * dh * (hq + 2 * hkv) + hq * dh * d
        mlp = (3 if self.mlp_gated else 2) * d * f
        if self.family == "moe":
            mlp *= self.num_experts
            mlp += d * self.num_experts  # router
        if self.family == "ssm" and self.ssm_variant == "mamba1":
            di, n_, r = self.d_inner, self.ssm_state, self.dt_rank
            per = d * 2 * di + di * self.ssm_conv + di * (r + 2 * n_) + r * di + di * n_ + 2 * di + di * d
            return v * d + self.num_layers * per
        if self.family == "hybrid":
            di, n_ = self.d_inner, self.ssm_state
            h = di // self.ssm_head_dim
            per_m = d * (2 * di + 2 * self.ssm_groups * n_ + h) + di * self.ssm_conv + 2 * h + di + di * d
            shared = attn + mlp
            return v * d + self.num_layers * per_m + shared
        n_layers = self.num_layers or (self.enc_layers + self.dec_layers)
        per = attn + mlp
        if self.family == "encdec":
            per_dec = 2 * attn + mlp  # self + cross
            return v * d + self.enc_layers * per + self.dec_layers * per_dec
        return v * d + n_layers * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for MODEL_FLOPS."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dh, hq, hkv = self.d_head, self.num_heads, self.num_kv_heads
        attn = d * dh * (hq + 2 * hkv) + hq * dh * d
        mlp_active = (3 if self.mlp_gated else 2) * d * f * self.experts_per_token
        return self.vocab_size * d + self.num_layers * (attn + mlp_active + d * self.num_experts)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family & layer pattern, tiny everything."""
    p = len(cfg.pattern)
    n_small = max(2 * p, 2)
    kw = dict(
        num_layers=min(cfg.num_layers, n_small) or 0,
        d_model=64, d_ff=128, vocab_size=512,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else 0,
        window=8 if cfg.window else None,
        max_position=4096,
        frontend_len=4 if cfg.frontend else 256,
        block_k=16,
        ssm_chunk=8,
    )
    if cfg.family == "moe":
        kw.update(num_experts=min(cfg.num_experts, 8),
                  experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=min(cfg.ssm_state, 8), ssm_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(num_layers=max(cfg.hybrid_period, 2) + 2,
                  hybrid_period=max(min(cfg.hybrid_period, 2), 2))
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, num_layers=0)
    if cfg.num_layers and cfg.layer_pattern:
        kw.update(num_layers=n_small)
    return cfg.replace(name=cfg.name + "-smoke", **kw)
