from repro.data.pipeline import DataConfig, TokenPipeline, host_shard

__all__ = ["DataConfig", "TokenPipeline", "host_shard"]
