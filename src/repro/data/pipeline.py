"""Deterministic, shardable, resumable synthetic token pipeline.

Every batch is a *pure function of (seed, step, shard)* via a counter-based
Philox generator — the fault-tolerance keystone (DESIGN.md §4):

- **resumable**: restoring a checkpoint at step S and continuing reproduces
  the exact batch sequence — no data-iterator state to snapshot;
- **straggler/failure mitigation**: any host can recompute any shard's batch
  (a rejoining or backup host needs no state handoff);
- **elastic**: re-sharding to a different host count at step S just changes
  (shard, num_shards) — global batch content is identical because shards
  partition the *global* batch deterministically.

Two corpora:
- ``lm``   — first-order Markov tokens (structured → a model can learn it;
             used by the convergence example/tests);
- ``copy`` — random prefix + its repetition (loss on the copied half drops
             fast — a sharp learnability signal).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus: str = "lm"              # lm | copy | uniform
    markov_branch: int = 4          # lm: successors per token


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.corpus in ("lm", "copy", "uniform"), cfg.corpus
        self.cfg = cfg
        if cfg.corpus == "lm":
            # Fixed sparse Markov transition table, derived from seed only.
            root = np.random.Generator(np.random.Philox(key=cfg.seed))
            self._succ = root.integers(
                0, cfg.vocab_size,
                size=(cfg.vocab_size, cfg.markov_branch)).astype(np.int64)

    # -- deterministic RNG per step -----------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        # Philox counter-based: the 2-word key fully determines the stream.
        # One stream per STEP (not per shard): every host synthesises the
        # same global batch and slices its shard, so shards exactly tile the
        # global batch at any host count (elasticity invariant, tested).
        return np.random.Generator(np.random.Philox(
            key=(self.cfg.seed * 0x9E3779B1, 7919 * step + 1)))

    # -- batch synthesis -----------------------------------------------------
    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        c = self.cfg
        if c.corpus == "uniform":
            return rng.integers(0, c.vocab_size, size=(n, c.seq_len))
        if c.corpus == "copy":
            half = c.seq_len // 2
            prefix = rng.integers(0, c.vocab_size, size=(n, half))
            return np.concatenate(
                [prefix, prefix[:, : c.seq_len - half]], axis=1)
        # lm: walk the Markov table
        toks = np.empty((n, c.seq_len), np.int64)
        toks[:, 0] = rng.integers(0, c.vocab_size, size=n)
        choices = rng.integers(0, c.markov_branch, size=(n, c.seq_len))
        for t in range(1, c.seq_len):
            toks[:, t] = self._succ[toks[:, t - 1], choices[:, t]]
        return toks

    def shard_batch(self, step: int, shard: int = 0, num_shards: int = 1
                    ) -> Dict[str, np.ndarray]:
        """The ``shard``-th contiguous slice of the global batch at ``step``."""
        c = self.cfg
        assert c.global_batch % num_shards == 0, (c.global_batch, num_shards)
        per = c.global_batch // num_shards
        toks = self._tokens(self._rng(step), c.global_batch).astype(np.int32)
        toks = toks[shard * per: (shard + 1) * per]
        batch = {"tokens": toks, "labels": toks.copy()}
        if c.corpus == "copy":
            mask = np.zeros_like(toks, np.float32)
            mask[:, c.seq_len // 2:] = 1.0       # score only the copied half
            batch["loss_mask"] = mask
        return batch

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        return self.shard_batch(step, 0, 1)


def host_shard(global_batch: int, host: int, num_hosts: int
               ) -> Tuple[int, int]:
    """(start, size) of this host's slice — pure arithmetic, no coordination."""
    per = global_batch // num_hosts
    return host * per, per
