"""Jit'd public wrapper for the streaming-attention Pallas kernel.

Accepts the model-layer layout (B, H, L, D), folds batch×head into the grid
axis, pads Lq/Lkv up to block multiples (padded kv is masked via ``kv_len``;
padded q rows are dropped), and picks MXU-aligned default block sizes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import make_table
from repro.kernels.streaming_attention.kernel import attention_3d


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "cap", "exp_mode",
                     "block_q", "block_k", "q_offset", "kv_len", "interpret"))
def streaming_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: Optional[float] = None, causal: bool = False,
                        window: Optional[int] = None,
                        cap: Optional[float] = None, exp_mode: str = "lut",
                        block_q: int = 512, block_k: int = 512,
                        q_offset: int = 0, kv_len: Optional[int] = None,
                        interpret: bool | None = None) -> jax.Array:
    """HASTILY streaming attention (Pallas kernel path).

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D), Hq % Hkv == 0.  ``q_offset``
    and ``kv_len`` must be static here (serving uses bucketed lengths); the
    pure-jnp path handles fully dynamic lengths.
    """
    if interpret is None:
        interpret = _use_interpret()
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = lkv
    block_q = max(8, min(block_q, lq))
    block_k = max(8, min(block_k, lkv))

    qp = _pad_to(q.reshape(b * hq, lq, d), 1, block_q)
    kp = _pad_to(k.reshape(b * hkv, lkv, d), 1, block_k)
    vp = _pad_to(v.reshape(b * hkv, lkv, d), 1, block_k)

    out = attention_3d(
        qp, kp, vp, make_table(),
        scale=float(scale), causal=causal, window=window, cap=cap,
        exp_mode=exp_mode, block_q=block_q, block_k=block_k,
        kv_len=int(kv_len), q_offset=int(q_offset), group=group,
        interpret=interpret)
    return out[:, :lq].reshape(b, hq, lq, d)
