"""Pure-jnp oracle for the streaming-attention kernel.

Delegates to the materialised-logits baseline in ``repro.core`` — the same
function used as the paper-baseline ("PUMA dataflow") arm of the A/Bs — so
kernel↔oracle agreement also certifies the kernel against the model code.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.streaming_attention import naive_attention


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None, causal: bool = False,
                  window: Optional[int] = None, cap: Optional[float] = None,
                  exp_mode: str = "lut", q_offset: int = 0,
                  kv_len: Optional[int] = None) -> jax.Array:
    """(B, Hq, Lq, D) × (B, Hkv, Lkv, D) → (B, Hq, Lq, D)."""
    return naive_attention(q, k, v, scale=scale, causal=causal, window=window,
                           cap=cap, exp_mode=exp_mode, q_offset=q_offset,
                           kv_len=kv_len)
