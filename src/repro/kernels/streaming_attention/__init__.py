from repro.kernels.streaming_attention.ops import streaming_attention
from repro.kernels.streaming_attention.ref import attention_ref

__all__ = ["streaming_attention", "attention_ref"]
