"""Pallas TPU kernel: fine-grained-pipelined (streaming) attention, paper §IV.

The paper streams one input row at a time through ``QKᵀ → softmax → ·V`` so
the l×l logit matrix never exists.  On TPU the pipeline unit is an MXU tile,
not an SRAM word line: the grid is

    (q_head, q_block, kv_block)           kv innermost, sequential

and each step computes a ``(block_q, block_k)`` logits tile, updates the
online-softmax carry ``(m, l, acc)`` held in VMEM scratch, and emits the
normalised output on the last kv step.  VMEM working set per step:

    q tile        block_q × d        (revisited across kv steps — stays put)
    k,v tiles     block_k × d        (the "vector" flowing through the pipe)
    logits tile   block_q × block_k
    carry         block_q × (2·128 + d)

With block_q = block_k = 512 and d = 128 that is ~1.8 MiB — far under the
~16 MiB v5e VMEM budget and all matmul dims are multiples of 128 (MXU
aligned).  The exponential inside the softmax is the UCLM LUT decomposition
(``lut_exp_block`` — one-hot × table matmuls on the MXU), so this kernel is
the full HASTILY story in one place: attention whose softmax *and* whose
memory footprint are both restructured.

GQA: q heads are enumerated as B·Hq programs; the k/v index maps divide by
the group size so each kv head's tiles are shared by its G query heads.
Causal/window masking supports fully-masked-block *skipping*: the kv grid
axis still visits the block, but ``@pl.when`` guards the matmuls so the MXU
does no work for blocks strictly above the causal diagonal or outside the
sliding window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lut_exp import K as LUT_K
from repro.core.lut_softmax import NEG_INF
from repro.kernels.lut_exp.kernel import lut_exp_block

LANES = 128  # m/l carries are broadcast across one lane register


def _exp_fn(mode: str, table):
    if mode == "lut":
        return lambda x: lut_exp_block(x, table, order=1)
    if mode == "lut0":
        return lambda x: lut_exp_block(x, table, order=0)
    return jnp.exp


def attention_kernel(q_ref, k_ref, v_ref, table_ref, o_ref,
                     m_ref, l_ref, acc_ref, *,
                     scale: float, causal: bool, window: Optional[int],
                     cap: Optional[float], exp_mode: str,
                     block_q: int, block_k: int, kv_len: int,
                     q_offset: int, num_kv_blocks: int):
    _, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    exp = _exp_fn(exp_mode, table_ref[...])

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- static-shape index vectors for this (q_block, kv_block) pair ---
    q_idx = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_idx = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Fully-masked-block skip: with causal masking, any kv block whose first
    # index exceeds the last q position contributes nothing.
    run = jnp.asarray(True)
    if causal:
        run &= (j * block_k) <= (q_offset + (i + 1) * block_q - 1)
    if window is not None:
        # block entirely left of every q position's window start
        run &= ((j + 1) * block_k - 1) >= (q_offset + i * block_q - window + 1)
    run &= (j * block_k) < kv_len

    @pl.when(run)
    def _step():
        q = q_ref[...].astype(jnp.float32)                  # (bq, d)
        k = k_ref[...].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)

        mask = kv_idx < kv_len
        if causal:
            mask &= kv_idx <= q_idx
        if window is not None:
            mask &= (q_idx - kv_idx) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = exp(s - m_new)                                   # LUT softmax numerator
        p = jnp.where(mask, p, 0.0)
        alpha = exp(m_prev - m_new)                          # (bq, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)                   # (bk, d)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, d)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == num_kv_blocks - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "cap", "exp_mode",
                     "block_q", "block_k", "kv_len", "q_offset", "group",
                     "interpret"))
def attention_3d(q: jax.Array, k: jax.Array, v: jax.Array, table: jax.Array,
                 *, scale: float, causal: bool, window: Optional[int],
                 cap: Optional[float], exp_mode: str, block_q: int,
                 block_k: int, kv_len: int, q_offset: int, group: int,
                 interpret: bool = False) -> jax.Array:
    """q: (BHq, Lq, D), k/v: (BHkv, Lkv, D); Lq % block_q == Lkv % block_k == 0."""
    bhq, lq, d = q.shape
    bhkv, lkv, dv = k.shape
    assert bhq == bhkv * group and lq % block_q == 0 and lkv % block_k == 0
    nq, nk = lq // block_q, lkv // block_k

    kernel = functools.partial(
        attention_kernel, scale=scale, causal=causal, window=window, cap=cap,
        exp_mode=exp_mode, block_q=block_q, block_k=block_k, kv_len=kv_len,
        q_offset=q_offset, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((None, block_k, dv), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, LUT_K), lambda b, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, lq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, dv), jnp.float32),      # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v, table.reshape(1, LUT_K))
