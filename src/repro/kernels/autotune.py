"""Roofline-guided autotuner for the tiled varlen paged-attention kernel.

The paper's throughput comes from co-designing kernel dataflow with the
memory hierarchy; CHARM-style CDSE does the software half by *enumerating*
tile candidates against an analytic resource model instead of hand-picking
them.  This module is that sweep for ``paged_attention_varlen``'s block
shapes:

    candidate  = (block_q, block_pages, dequant granularity)
    score      = perfmodel roofline (bytes-moved / FLOPs / grid steps)
               over a representative mix of serving steps
    validate   = optionally time the real kernel (jnp scan or interpret
                 mode on CPU CI, the compiled Pallas lowering on TPU)
    persist    = JSON table keyed ``{model}::{platform}`` that
                 ``core/attention_api.py`` consults at backend resolution

``KernelConfig`` is the unit of currency: frozen, hashable, safe to close
over as a static value in a jitted serving step.  ``source`` records
provenance ("default" | "tuned") so benchmark regressions are attributable
to the config that produced them.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.perfmodel.model import (PlatformSpec, platform_spec,
                                   varlen_attention_roofline,
                                   varlen_attention_traffic)

#: segments of one serving step: ``(n_new_tokens, kv_len_after)`` per lane
Workload = Sequence[Tuple[int, int]]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Block shapes of the varlen paged-attention kernel (static facts)."""
    block_q: int = 8            # q-block rows; 1 = untiled batch=T dataflow
    block_pages: Optional[int] = None   # pages per scan step (None = auto)
    dequant: str = "block"      # int8 scale granularity: "block" | "page"
    source: str = "default"     # "default" | "tuned" — provenance

    def describe(self) -> Dict[str, object]:
        return {"block_q": self.block_q, "block_pages": self.block_pages,
                "dequant": self.dequant, "source": self.source}


DEFAULT_CONFIG = KernelConfig()


@dataclasses.dataclass(frozen=True)
class KernelGeom:
    """The model/pool facts the roofline needs about one deployment."""
    hq: int
    hkv: int
    head_dim: int
    page_size: int
    kv_bytes: int = 4           # 4 = f32 pool, 1 = int8 (+ scale planes)

    @property
    def scaled(self) -> bool:
        return self.kv_bytes == 1


def geom_for(cfg, *, page_size: int, quantized: bool = False) -> KernelGeom:
    """KernelGeom from a ModelConfig (``num_heads``/``num_kv_heads``/
    ``d_head``) plus the engine's pool facts."""
    return KernelGeom(hq=cfg.num_heads, hkv=cfg.num_kv_heads or cfg.num_heads,
                      head_dim=cfg.d_head, page_size=page_size,
                      kv_bytes=1 if quantized else 4)


# --------------------------------------------------------------------------
# candidate space + representative workloads
# --------------------------------------------------------------------------

def candidate_space(page_size: int, *, max_block_q: int = 32,
                    max_block_pages: int = 8) -> List[KernelConfig]:
    """Every (Bq, pages-per-step, dequant) the sweep considers.

    Bq = 1 (the untiled baseline) stays in the space on purpose: on an
    all-decode workload tiling buys nothing, and the sweep should be able
    to say so rather than assume tiling always wins.
    """
    bqs = [b for b in (1, 4, 8, 16, 32) if b <= max_block_q]
    bps = [p for p in (1, 2, 4, 8) if p <= max_block_pages]
    return [KernelConfig(block_q=bq, block_pages=bp, dequant=dq)
            for bq in bqs for bp in bps
            for dq in ("block", "page")]


def default_workloads(*, lanes: int = 8, chunk: int = 32,
                      decode_ctx: int = 256) -> Dict[str, Workload]:
    """The serving-step mix the score integrates over: the two full-width
    extremes the padded dispatch used to special-case, plus the mixed step
    ragged batching exists for."""
    return {
        "all_decode": [(1, decode_ctx)] * lanes,
        "all_prefill": [(chunk, chunk)] * lanes,
        "mixed": [(chunk, chunk), (chunk, 2 * chunk)]
                 + [(1, decode_ctx)] * (lanes - 2),
    }


# --------------------------------------------------------------------------
# scoring + optional measurement
# --------------------------------------------------------------------------

def predict_step_s(config: KernelConfig, geom: KernelGeom,
                   workloads: Dict[str, Workload],
                   spec: PlatformSpec) -> float:
    """Roofline-predicted seconds summed over the workload mix."""
    bp = config.block_pages or max(1, 128 // max(geom.page_size, 1))
    total = 0.0
    for segments in workloads.values():
        traffic = varlen_attention_traffic(
            segments, block_q=config.block_q, block_pages=bp,
            page_size=geom.page_size, hq=geom.hq, hkv=geom.hkv,
            head_dim=geom.head_dim, kv_bytes=geom.kv_bytes,
            scaled=geom.scaled)
        total += varlen_attention_roofline(
            spec, traffic, block_pages=bp, dequant=config.dequant)
    return total


def measure_step_s(config: KernelConfig, geom: KernelGeom,
                   workloads: Dict[str, Workload], *,
                   interpret: Optional[bool] = None,
                   iters: int = 3) -> float:
    """Time the real kernel on a synthetic pool built from the workloads.

    ``interpret=None`` is the platform default (jnp scan on CPU, compiled
    Pallas on TPU); ``interpret=True`` forces the Pallas kernel in
    interpret mode — the CPU-CI way to validate the kernel lowering itself.
    Returns the *minimum* over ``iters`` repetitions — the noise-robust
    microbenchmark estimator (scheduler hiccups only ever add time).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention import (paged_attention_varlen,
                                               varlen_positions)

    rng = np.random.default_rng(0)
    best_total = 0.0
    for segments in workloads.values():
        lens_new = [n for n, _ in segments]
        kv_lens = [kv for _, kv in segments]
        cu = np.concatenate([[0], np.cumsum(lens_new)]).astype(np.int32)
        t = int(cu[-1])
        ps = geom.page_size
        per_lane = max(-(-max(kv_lens) // ps), 1)
        n_pages = per_lane * len(segments)
        shape = (n_pages + 1, geom.hkv, ps, geom.head_dim)
        if geom.scaled:
            k_pool = jnp.asarray(
                rng.integers(-127, 127, size=shape).astype(np.int8))
            v_pool = jnp.asarray(
                rng.integers(-127, 127, size=shape).astype(np.int8))
            k_scale = jnp.asarray(
                rng.uniform(0.01, 0.03, size=shape[:3]).astype(np.float32))
            v_scale = k_scale
        else:
            k_pool = jnp.asarray(
                rng.normal(size=shape).astype(np.float32))
            v_pool = jnp.asarray(
                rng.normal(size=shape).astype(np.float32))
            k_scale = v_scale = None
        q = jnp.asarray(
            rng.normal(size=(t, geom.hq, geom.head_dim)).astype(np.float32))
        tbl = np.zeros((t, per_lane), np.int32)
        for i in range(len(segments)):
            tbl[cu[i]:cu[i + 1]] = np.arange(
                i * per_lane, (i + 1) * per_lane, dtype=np.int32)
        token_pages = jnp.asarray(tbl)
        q_pos = jnp.asarray(varlen_positions(cu, kv_lens))

        def run(q, cu_d):
            return paged_attention_varlen(
                q, k_pool, v_pool, token_pages, q_pos, cu_seqlens=cu_d,
                k_scale=k_scale, v_scale=v_scale,
                block_q=config.block_q, block_pages=config.block_pages,
                dequant=config.dequant, interpret=interpret)

        fn = jax.jit(run)
        cu_d = jnp.asarray(cu)
        fn(q, cu_d).block_until_ready()       # compile outside the clock
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn(q, cu_d).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        best_total += best
    return best_total


def tune(geom: KernelGeom, *, platform: Optional[str] = None,
         workloads: Optional[Dict[str, Workload]] = None,
         candidates: Optional[List[KernelConfig]] = None,
         measure: bool = False, interpret: Optional[bool] = None,
         top_k_measure: int = 3) -> Tuple[KernelConfig, List[Dict]]:
    """Sweep the candidate space; return (winner, per-candidate report).

    Pure roofline by default; ``measure=True`` re-ranks the roofline's
    ``top_k_measure`` finalists by timing the real kernel — the cheap
    analytic model prunes, the hardware decides.

    The incumbent ``DEFAULT_CONFIG`` is always in the sweep, so the winner
    predicts no worse than the default *by construction* — CI asserts
    exactly that (measured times are too noisy at CI scale to gate on).
    """
    import jax
    plat = platform or jax.default_backend()
    spec = platform_spec(plat)
    wl = workloads or default_workloads()
    cands = list(candidates or candidate_space(geom.page_size))
    if DEFAULT_CONFIG not in cands:
        cands.append(DEFAULT_CONFIG)
    report = []
    for c in cands:
        report.append({"config": c.describe(),
                       "predicted_s": predict_step_s(c, geom, wl, spec)})
    order = sorted(range(len(cands)), key=lambda i: report[i]["predicted_s"])
    if measure:
        finalists = order[:max(1, top_k_measure)]
        for i in finalists:
            report[i]["measured_s"] = measure_step_s(
                cands[i], geom, wl, interpret=interpret)
        best = min(finalists, key=lambda i: report[i]["measured_s"])
    else:
        best = order[0]
    winner = dataclasses.replace(cands[best], source="tuned")
    return winner, report


# --------------------------------------------------------------------------
# persistence: the per-(model, platform) table
# --------------------------------------------------------------------------

def table_path(path: Optional[os.PathLike] = None) -> Path:
    """Resolution order: explicit arg → $REPRO_AUTOTUNE_PATH → the
    committed repo table next to the model configs."""
    if path is not None:
        return Path(path)
    env = os.environ.get("REPRO_AUTOTUNE_PATH")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[1] / "configs" / "autotune.json"


def _key(model: str, platform: str) -> str:
    return f"{model}::{platform}"


def load_table(path: Optional[os.PathLike] = None) -> Dict[str, Dict]:
    p = table_path(path)
    if not p.exists():
        return {}
    try:
        return json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return {}


def save_config(model: str, platform: str, config: KernelConfig, *,
                path: Optional[os.PathLike] = None) -> Path:
    p = table_path(path)
    table = load_table(p)
    table[_key(model, platform)] = config.describe()
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    return p


def resolve_config(model: str, platform: Optional[str] = None, *,
                   path: Optional[os.PathLike] = None) -> KernelConfig:
    """Tuned config for (model, platform) if persisted, else the default.

    Falls back ``model::platform`` → ``default::platform`` →
    ``DEFAULT_CONFIG`` so a table tuned for one model still seeds its
    platform's siblings.
    """
    if platform is None:
        import jax
        platform = jax.default_backend()
    table = load_table(path)
    for key in (_key(model, platform), _key("default", platform)):
        entry = table.get(key)
        if entry is not None:
            known = {f.name for f in dataclasses.fields(KernelConfig)}
            entry = {k: v for k, v in entry.items() if k in known}
            return KernelConfig(**{**entry, "source": "tuned"})
    return DEFAULT_CONFIG


# --------------------------------------------------------------------------
# process-wide active config (what `attention()` consults)
# --------------------------------------------------------------------------

_ACTIVE: Optional[KernelConfig] = None


def set_active_config(config: Optional[KernelConfig]) -> None:
    """Pin the config `attention()` uses for ragged calls that don't pass
    one explicitly (EngineCore pins its resolved config at init).  ``None``
    reverts to on-disk resolution."""
    global _ACTIVE
    _ACTIVE = config


def active_config() -> KernelConfig:
    if _ACTIVE is not None:
        return _ACTIVE
    return resolve_config("default")
