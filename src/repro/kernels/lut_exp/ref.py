"""Pure-jnp oracle for the lut_exp kernel — delegates to the shared core math.

A single source of truth (``repro.core.lut_exp``) backs both the model code
and this oracle, so a kernel↔oracle allclose is also a kernel↔model check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp as _core_lut_exp


def lut_exp_ref(x: jax.Array, *, order: int = 1) -> jax.Array:
    return _core_lut_exp(x.astype(jnp.float32), order=order)
