from repro.kernels.lut_exp.ops import lut_exp
from repro.kernels.lut_exp.ref import lut_exp_ref

__all__ = ["lut_exp", "lut_exp_ref"]
