"""Jit'd public wrapper for the lut_exp Pallas kernel.

Handles arbitrary shapes/dtypes: flattens to (M, 128) lanes, pads M to the
block size, dispatches the kernel (interpret=True off-TPU), and restores the
original shape/dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lut_exp import K, make_table
from repro.kernels.lut_exp.kernel import lut_exp_2d


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("order", "block_m", "interpret"))
def lut_exp(x: jax.Array, *, order: int = 1, block_m: int = 256,
            interpret: bool | None = None) -> jax.Array:
    """LUT e^x, any shape/dtype, via the Pallas UCLM kernel."""
    if interpret is None:
        interpret = _use_interpret()
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    rows = -(-n // K)
    rows_pad = -(-rows // block_m) * block_m
    # Pad with 0 (exp(0)=1; padded lanes are dropped below).
    flat = jnp.pad(flat, (0, rows_pad * K - n))
    out = lut_exp_2d(flat.reshape(rows_pad, K), make_table(K),
                     order=order, block_m=block_m, interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape).astype(orig_dtype)
