"""Pallas TPU kernel for the UCLM LUT-exponential (paper §III-A/B).

The paper's UCLM performs the ``2^(d/K)`` table lookup *inside the same SRAM
array that does the MVMs*.  The TPU-native statement of that property: the
lookup is executed as a **one-hot × table matmul on the MXU** — the same
systolic unit that runs the surrounding matrix products — rather than on the
VPU or via scalar gathers.  K = 128 is exactly one TPU lane width, so the
table occupies a single (1, 128) VMEM row (one VREG row), mirroring the
paper's "one table per 64×64 array" layout (Fig. 4a).

Blocking: the input is viewed as (M, 128) lanes; each grid step processes a
``(block_m, 128)`` VMEM tile.  Per tile the working set is

    x tile          block_m × 128 × 4 B
    one-hot         (block_m·128) × 128 × 4 B   (MXU operand)
    table           128 × 4 B

so ``block_m = 256`` keeps the one-hot operand at 16 MiB — fits v5e VMEM
(~128 KiB x tile + 16 MiB one-hot is too big; we therefore build the one-hot
in ``sub`` slabs of 8 rows: 8·128×128×4 B = 512 KiB).  The slab loop is a
``jax.lax.fori_loop`` inside the kernel, so the (M·128)×128 one-hot never
materialises — the same "never materialise the big intermediate" discipline
as the streaming-attention kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lut_exp import K, LN2, LOG2E, UNDERFLOW_X

# Rows of the input tile exponentiated per MXU one-hot matmul.
SLAB = 8


def _pow2_int_f32(n: jax.Array) -> jax.Array:
    """Exact 2^n by exponent-field construction (kernel-local copy)."""
    n_i = jnp.clip(n, -127.0, 127.0).astype(jnp.int32)
    bits = jnp.where(n_i <= -127, 0, (n_i + 127) << 23)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def mxu_table_lookup(d_i: jax.Array, table: jax.Array,
                     slab: int = SLAB) -> jax.Array:
    """T[d] for a 2D int32 index block, as one-hot × table MXU matmuls.

    This is the UCLM property: the lookup runs on the matmul fabric.  The
    one-hot is built ``slab`` rows at a time so it never exceeds
    slab·cols×K×4 B of VMEM.  Shared by the lut_exp and streaming-attention
    kernels.
    """
    rows, cols = d_i.shape
    table = table.reshape(K, 1)
    if rows % slab:
        slab = 1

    def slab_body(i, looked):
        d_slab = jax.lax.dynamic_slice(d_i, (i * slab, 0), (slab, cols))
        flat = d_slab.reshape(slab * cols)
        iota = jax.lax.broadcasted_iota(jnp.int32, (slab * cols, K), 1)
        onehot = (flat[:, None] == iota).astype(jnp.float32)
        vals = jax.lax.dot_general(
            onehot, table, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(slab, cols)
        return jax.lax.dynamic_update_slice(looked, vals, (i * slab, 0))

    return jax.lax.fori_loop(
        0, rows // slab, slab_body, jnp.zeros((rows, cols), jnp.float32))


def lut_exp_block(x: jax.Array, table: jax.Array, *, order: int = 1,
                  slab: int = SLAB) -> jax.Array:
    """e^x for a 2D f32 block — the kernel-side LUT-exp decomposition."""
    t = x * LOG2E
    n = jnp.floor(t)
    fk = (t - n) * K
    d = jnp.clip(jnp.floor(fk), 0.0, float(K - 1))
    r = fk - d
    looked = mxu_table_lookup(d.astype(jnp.int32), table, slab)
    corr = 1.0 if order == 0 else 1.0 + r * (LN2 / K)
    out = _pow2_int_f32(n) * looked * corr
    return jnp.where(x < UNDERFLOW_X, 0.0, out)


def lut_exp_kernel(x_ref, table_ref, o_ref, *, order: int, block_m: int):
    """One (block_m, K) tile: e^x = 2^n · T[d] · (1 + r·ln2/K)."""
    x = x_ref[...].astype(jnp.float32)                       # (bm, K)
    o_ref[...] = lut_exp_block(x, table_ref[...], order=order)


@functools.partial(jax.jit, static_argnames=("order", "block_m", "interpret"))
def lut_exp_2d(x: jax.Array, table: jax.Array, *, order: int = 1,
               block_m: int = 256, interpret: bool = False) -> jax.Array:
    """e^x for an (M, 128) f32 array, M a multiple of ``block_m``."""
    m, k = x.shape
    assert k == K and m % block_m == 0, (x.shape, block_m)
    kernel = functools.partial(lut_exp_kernel, order=order, block_m=block_m)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, K), jnp.float32),
        interpret=interpret,
    )(x, table.reshape(1, K))
