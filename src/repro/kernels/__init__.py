"""Pallas TPU kernels for HASTILY's compute hot-spots.

Three kernels, each ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling) +
``ops.py`` (jit'd wrapper; interpret=True off-TPU) + ``ref.py`` (pure-jnp
oracle):

- ``lut_exp``              — the UCLM LUT exponential; table lookup as a
                             one-hot × table matmul on the MXU (paper §III).
- ``streaming_attention``  — fine-grained-pipelined flash-style attention
                             with the LUT softmax inside (paper §IV).
- ``paged_attention``      — decode attention that reads KV pages in place
                             through the page table (scalar-prefetch index
                             maps; online-softmax combine across pages).
- ``int8_matmul``          — int8×int8→int32 tiled matmul (paper §V).
"""
from repro.kernels.lut_exp import lut_exp, lut_exp_ref
from repro.kernels.streaming_attention import streaming_attention, attention_ref
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_reference)
from repro.kernels.int8_matmul import int8_matmul, int8_matmul_ref

__all__ = ["lut_exp", "lut_exp_ref",
           "streaming_attention", "attention_ref",
           "paged_attention", "paged_attention_reference",
           "int8_matmul", "int8_matmul_ref"]
