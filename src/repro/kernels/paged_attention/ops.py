"""Public paged-attention entry point: kernel on TPU, jnp reference off it.

Accepts the serving layout directly — q ``(B, Hq, Lq, D)``, page pools
``(N, Hkv, page_size, D)``, a page table ``(B, P)`` and per-lane live
lengths ``(B,)`` — so the engine hands its pool straight in with no copies.
``Lq == 1`` is decode; ``Lq > 1`` is a chunked-prefill block whose rows sit
at positions ``kv_len - Lq + i`` (causal intra-chunk mask implied).
Optional ``k_scale``/``v_scale`` pools switch on the INT8 path (per-row
dequant inside the page loop).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import make_table
from repro.kernels.paged_attention.ref import paged_attention_reference


def _use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    page_table: jax.Array, kv_len: jax.Array, *,
                    scale: Optional[float] = None,
                    cap: Optional[float] = None,
                    window: Optional[int] = None,
                    exp_mode: str = "lut",
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None,
                    block_pages: Optional[int] = None,
                    dequant: str = "block",
                    interpret: Optional[bool] = None) -> jax.Array:
    """Attention through the page table (no gathered cache view).

    q: (B, Hq, Lq, D) — a single decode row (Lq == 1) or a chunked-prefill
    block (Lq > 1) whose row ``i`` holds absolute position
    ``kv_len - Lq + i``; k_pool/v_pool: (N, Hkv, page_size, D); page_table:
    (B, P) int32; kv_len: (B,) live rows per lane, query chunk included.

    ``interpret`` selects the implementation: ``None`` (default) dispatches
    by platform — the compiled Pallas kernel on TPU, the jnp page-block
    scan everywhere else; ``True`` forces the Pallas kernel in interpret
    mode (tests exercise the kernel off-TPU this way); ``False`` forces the
    natively-compiled kernel and therefore requires a TPU.
    """
    b, hq, lq, d = q.shape
    hkv = k_pool.shape[1]
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    if scale is None:
        scale = d ** -0.5
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))

    if interpret is None and not _use_kernel():
        return paged_attention_reference(
            q, k_pool, v_pool, page_table, kv_len, scale=float(scale),
            cap=cap, window=window, exp_mode=exp_mode, k_scale=k_scale,
            v_scale=v_scale, block_pages=block_pages, dequant=dequant)
    if interpret is False and not _use_kernel():
        raise ValueError(
            "paged_attention(interpret=False) forces the natively-compiled "
            "Pallas kernel, which needs a TPU (current backend: "
            f"{jax.default_backend()!r}); pass interpret=True for interpret "
            "mode or interpret=None for the platform default")

    # The Pallas kernel walks one page per grid step, so its dequant is
    # inherently per-page; the `dequant` knob only shapes the jnp scan.
    from repro.kernels.paged_attention.kernel import paged_attention_4d
    g = hq // hkv
    out = paged_attention_4d(
        q.reshape(b, hkv, g * lq, d), k_pool, v_pool, k_scale, v_scale,
        page_table, kv_len, make_table(), scale=float(scale), cap=cap,
        window=window, exp_mode=exp_mode, group=g, q_len=lq,
        interpret=bool(interpret) if interpret is not None
        else not _use_kernel())
    return out.reshape(b, hq, lq, v_pool.shape[-1])
