from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_reference
from repro.kernels.paged_attention.varlen import (
    paged_attention_varlen, paged_attention_varlen_reference,
    q_block_layout, validate_cu_seqlens, varlen_positions)

__all__ = ["paged_attention", "paged_attention_reference",
           "paged_attention_varlen", "paged_attention_varlen_reference",
           "q_block_layout", "validate_cu_seqlens", "varlen_positions"]
