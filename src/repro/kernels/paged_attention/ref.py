"""Pure-jnp paged-attention reference (the CPU/CI code path).

Semantics shared with the Pallas kernel (``kernel.py``): each batch lane's
query rows attend over that lane's KV pages *in place* in the pool, walking
the page table block by block with an online-softmax running (max, sum,
accumulator) combine — the paper's multicore partial-max/partial-sum gather
(§III-B2) applied across page blocks instead of cores.  No contiguous
``(B, …, P·page_size, …)`` view of the cache is ever materialised: each scan
step gathers only ``block_pages`` pages per lane (an O(block) transient that
feeds compute and dies), so traffic is one read of the live KV rows plus
nothing else.

Two query shapes share this one code path:

- **decode** — ``Lq == 1``: the single query row sits at position
  ``kv_len - 1`` and the length mask doubles as the causal mask;
- **chunked prefill** — ``Lq > 1``: query row ``i`` holds absolute position
  ``kv_len - Lq + i`` (the chunk is the *last* ``Lq`` live rows, written to
  pages by the caller before attending), so causality is the per-row bound
  ``row ≤ kv_len - Lq + i`` — a causal intra-chunk mask on the diagonal
  block and a plain length mask on everything before it.

Logical row order is the page-table order: the row at table slot ``p``,
in-page offset ``o`` holds absolute position ``p·page_size + o``.  Sliding
windows reduce to a position-difference test against each query row's
position.  Rows whose position underflows 0 (idle lanes / right-align
padding in a mixed serving batch) mask everything and emit zeros — the
caller never samples them.

INT8 pools dequantise per page block inside the scan body — the resident
cache stays int8; only the O(block) transient is f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp
from repro.core.lut_softmax import NEG_INF, softcap

_EXP_FNS = {
    "lut": lambda x: lut_exp(x, order=1),
    "lut0": lambda x: lut_exp(x, order=0),
    "exact": jnp.exp,
}


def default_block_pages(page_size: int, block_k: int = 128) -> int:
    """Pages per scan step so one block is ~``block_k`` KV rows."""
    return max(1, block_k // max(page_size, 1))


def paged_attention_reference(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, page_table: jax.Array,
                              kv_len: jax.Array, *,
                              scale: Optional[float] = None,
                              cap: Optional[float] = None,
                              window: Optional[int] = None,
                              exp_mode: str = "lut",
                              k_scale: Optional[jax.Array] = None,
                              v_scale: Optional[jax.Array] = None,
                              block_pages: Optional[int] = None,
                              dequant: str = "block") -> jax.Array:
    """Attention through a page table: decode row or prefill chunk.

    q: (B, Hq, Lq, D) — query row ``i`` sits at absolute position
    ``kv_len - Lq + i`` (decode is the ``Lq == 1`` special case);
    k_pool/v_pool: (N, Hkv, page_size, D) page pools with ``Hq % Hkv == 0``
    (GQA); page_table: (B, P) physical page per table slot (idle slots may
    point anywhere valid — the causal/length mask drops them); kv_len: (B,)
    live rows per lane *including* the query chunk.  Optional
    k_scale/v_scale (N, Hkv, page_size) mark int8 pools (per-row dequant
    scales).  ``dequant`` sets the scale-application granularity inside the
    scan body — ``"block"`` multiplies the whole gathered block at once,
    ``"page"`` multiplies page by page (numerically identical; the knob
    exists so the autotuner can trade one wide multiply against page-sized
    ones that fuse into the per-page DMA on real hardware).  Returns
    (B, Hq, Lq, D) in q's dtype.
    """
    if dequant not in ("block", "page"):
        raise ValueError(f"dequant must be 'block' or 'page', got {dequant!r}")
    b, hq, lq, d = q.shape
    n, hkv, ps, dv = v_pool.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    g = hq // hkv
    p = page_table.shape[1]
    if scale is None:
        scale = d ** -0.5
    exp_fn = _EXP_FNS[exp_mode]

    bp = block_pages or default_block_pages(ps)
    bp = min(bp, p)
    nb = -(-p // bp)
    pad = nb * bp - p
    # Padded table slots index page 0 harmlessly: their structural rows are
    # >= P·ps >= kv_len for every lane, so the length mask drops them.
    tbl = jnp.pad(page_table, ((0, 0), (0, pad))) if pad else page_table
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    # (B, Lq) absolute position of each query row (the chunk is the tail of
    # the live rows); the causal bound per row is q_pos itself.
    q_pos = (kv_len[:, None] - lq
             + jnp.arange(lq, dtype=jnp.int32)[None, :])
    qg = q.astype(jnp.float32).reshape(b, hkv, g, lq, d)

    def gather_block(pool, ids):
        blk = jnp.take(pool, ids, axis=0)                  # (B, bp, Hkv, ...)
        blk = jnp.moveaxis(blk, 1, 2)                      # (B, Hkv, bp, ...)
        s = blk.shape
        return blk.reshape(s[:2] + (bp * ps,) + s[4:])     # rows contiguous

    def body(carry, j):
        m, l, acc = carry
        ids = jax.lax.dynamic_slice(tbl, (0, j * bp), (b, bp))   # (B, bp)
        k_blk = gather_block(k_pool, ids).astype(jnp.float32)
        v_blk = gather_block(v_pool, ids).astype(jnp.float32)
        if k_scale is not None:
            ks = gather_block(k_scale, ids)                # (B, Hkv, bp*ps)
            vs = gather_block(v_scale, ids)
            if dequant == "page":
                k_blk = jnp.concatenate(
                    [k_blk[..., i * ps:(i + 1) * ps, :]
                     * ks[..., i * ps:(i + 1) * ps, None]
                     for i in range(bp)], axis=-2)
                v_blk = jnp.concatenate(
                    [v_blk[..., i * ps:(i + 1) * ps, :]
                     * vs[..., i * ps:(i + 1) * ps, None]
                     for i in range(bp)], axis=-2)
            else:
                k_blk = k_blk * ks[..., None]
                v_blk = v_blk * vs[..., None]
        row = j * bp * ps + jnp.arange(bp * ps, dtype=jnp.int32)  # structural
        # Causal-within-chunk + length mask in one test: (B, Lq, bk).
        mask = row[None, None, :] <= q_pos[:, :, None]
        if window is not None:
            mask &= (q_pos[:, :, None] - row[None, None, :]) < window
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_blk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pw = jnp.where(mask[:, None, None], exp_fn(s - m_new[..., None]), 0.0)
        alpha = exp_fn(m - m_new)
        l_new = l * alpha + jnp.sum(pw, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", pw, v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, lq), jnp.float32),
            jnp.zeros((b, hkv, g, lq, dv), jnp.float32))
    # Unrolling lets XLA:CPU fuse/parallelise across page blocks — measured
    # ~4x on memory-bound shapes vs a rolled scan — while the scan skeleton
    # still bounds live transients to O(unroll · block) rows.
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  jnp.arange(nb, dtype=jnp.int32),
                                  unroll=min(nb, 8))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, dv).astype(q.dtype)
