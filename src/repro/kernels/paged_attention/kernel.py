"""Pallas TPU kernel: paged attention — KV pages read in place.

The serving pool keeps KV as ``(num_pages, Hkv, page_size, D)``; each lane's
logical sequence is its page table row.  The grid is

    (batch, kv_head, page_slot)           page_slot innermost, sequential

and the *page table is a scalar-prefetch operand*: the k/v BlockSpec index
maps dereference ``tbl_ref[b, j]`` so the DMA engine streams exactly the
physical page each grid step needs — no gathered contiguous copy of the
cache is ever built in HBM (the PR-1 gather this kernel deletes).  Each step
loads one ``(page_size, D)`` page tile, computes the ``(G·Lq, page_size)``
logits tile for the lane's G grouped query heads × Lq query rows, and folds
it into the online-softmax carry ``(m, l, acc)`` in VMEM scratch — the
paper's multicore partial-max/partial-sum gather (§III-B2) across page
blocks.  The last page slot normalises and emits.

One kernel serves both serving phases:

- **decode** (``Lq == 1``): the query row sits at ``kv_len - 1`` and the
  live-length mask is the causal mask;
- **chunked prefill** (``Lq > 1``): query row ``i`` sits at absolute
  position ``kv_len - Lq + i`` (the chunk is the tail of the live rows,
  already written to its pages), so the mask is the per-row causal bound
  ``row ≤ kv_len - Lq + i`` — intra-chunk causal on the diagonal pages,
  plain length gating before them.

Dead pages cost no compute: ``@pl.when(j·page_size < kv_len[b])`` skips
every slot past the lane's live length (their DMAs still land on a valid
page — idle table slots point at the pool's scratch page).

The INT8 variant prefetch-loads the per-row scale page alongside the values
and dequantises inside the step, so quantised serving keeps its 2×-smaller
resident cache *and* the in-place read path.

Like the streaming kernel, the exponential is the paper's LUT decomposition
(``lut_exp_block``) so softmax runs on the MXU.  VMEM per step is one page
tile + the (G·Lq, page_size) logits + the carry — KiBs, far under budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lut_exp import K as LUT_K
from repro.core.lut_softmax import NEG_INF
from repro.kernels.lut_exp.kernel import lut_exp_block

LANES = 128  # m/l carries are broadcast across one lane register


def _exp_fn(mode: str, table):
    if mode == "lut":
        return lambda x: lut_exp_block(x, table, order=1)
    if mode == "lut0":
        return lambda x: lut_exp_block(x, table, order=0)
    return jnp.exp


def paged_attention_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                           ks_ref, vs_ref, table_ref, o_ref,
                           m_ref, l_ref, acc_ref, *,
                           scale: float, cap: Optional[float],
                           window: Optional[int], exp_mode: str,
                           page_size: int, num_slots: int, q_len: int,
                           quantized: bool):
    b, _, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    exp = _exp_fn(exp_mode, table_ref[...])
    kv_len = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Live-page gate: slots at or past the lane's length hold no rows.
    @pl.when(j * page_size < kv_len)
    def _step():
        q = q_ref[...].astype(jnp.float32)                   # (G·Lq, D)
        k = k_ref[...].astype(jnp.float32)                   # (ps, D)
        v = v_ref[...].astype(jnp.float32)                   # (ps, D)
        if quantized:
            k = k * ks_ref[0][:, None]
            v = v * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G·Lq, ps)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)

        # Structural column index == absolute position (pages are in table
        # order); logits row r covers query index r % Lq, whose position is
        # kv_len - Lq + (r % Lq) — its own causal bound.  Decode (Lq == 1)
        # degenerates to the plain kv_len length mask.
        row = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_len
        q_pos = kv_len - q_len + qi
        mask = row <= q_pos
        if window is not None:
            mask &= (q_pos - row) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                # (G·Lq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, exp(s - m_new), 0.0)
        alpha = exp(m_prev - m_new)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (G·Lq, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == num_slots - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "cap", "window", "exp_mode", "group", "q_len",
                     "interpret"))
def paged_attention_4d(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       k_scale: Optional[jax.Array],
                       v_scale: Optional[jax.Array],
                       page_table: jax.Array, kv_len: jax.Array,
                       table: jax.Array, *, scale: float,
                       cap: Optional[float], window: Optional[int],
                       exp_mode: str, group: int, q_len: int = 1,
                       interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G·Lq, D) with row r ↔ (head group r // Lq, query index
    r % Lq); pools: (N, Hkv, ps, D); page_table: (B, P) int32; kv_len: (B,)
    int32.  → (B, Hkv, G·Lq, D) in q's dtype."""
    b, hkv, rows, d = q.shape
    n, _, ps, dv = v_pool.shape
    p = page_table.shape[1]
    assert rows == group * q_len, (rows, group, q_len)
    quantized = k_scale is not None
    if not quantized:
        # Uniform kernel arity: dummy 1-page scale pools, never dereferenced
        # (the index map pins them to page 0 and `quantized` elides the load).
        k_scale = jnp.ones((1, hkv, ps), jnp.float32)
        v_scale = jnp.ones((1, hkv, ps), jnp.float32)

    kernel = functools.partial(
        paged_attention_kernel, scale=scale, cap=cap, window=window,
        exp_mode=exp_mode, page_size=ps, num_slots=p, q_len=q_len,
        quantized=quantized)

    def page_map(b_, h, j, tbl, lens):
        del lens
        return (tbl[b_, j], h, 0, 0)

    def scale_map(b_, h, j, tbl, lens):
        del lens
        return ((tbl[b_, j], h, 0) if quantized else (0, h, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page table + per-lane lengths
        grid=(b, hkv, p),
        in_specs=[
            pl.BlockSpec((None, None, rows, d),
                         lambda b_, h, j, tbl, lens: (b_, h, 0, 0)),
            pl.BlockSpec((None, None, ps, d), page_map),
            pl.BlockSpec((None, None, ps, dv), page_map),
            pl.BlockSpec((None, 1, ps), scale_map),
            pl.BlockSpec((None, 1, ps), scale_map),
            pl.BlockSpec((1, LUT_K),
                         lambda b_, h, j, tbl, lens: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rows, dv),
                               lambda b_, h, j, tbl, lens: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),  # running max
            pltpu.VMEM((rows, LANES), jnp.float32),  # running denominator
            pltpu.VMEM((rows, dv), jnp.float32),     # weighted accumulator
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, dv), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      q, k_pool, v_pool, k_scale, v_scale, table.reshape(1, LUT_K))
