"""Varlen (ragged) paged attention: one packed token stream, no lane padding.

The serving step used to be a right-aligned ``(lanes, C)`` block — every
decode lane paid ``C`` rows of padding whenever any lane prefilled.  The
ragged step flattens the batch into one dense stream of ``T = Σ live
tokens`` rows:

    q            (T, Hq, D)     packed query rows, lane segments abutting
    token_pages  (T, P)         each token's *own* page-table row (its
                                lane's pages; dead/padding rows all-scratch)
    q_pos        (T,)           each token's absolute position — which is
                                also its causal bound: token t attends
                                pool rows at positions ``0 .. q_pos[t]``
    cu_seqlens   (S+1,)         optional lane boundaries (cumulative token
                                counts); the kernel itself never needs them
                                — causality and length live entirely in
                                ``q_pos``/``token_pages`` — but callers use
                                them to pack/unpack and tests to validate.

The key identity: **varlen paged attention is paged decode at batch = T.**
A packed token is exactly a one-row lane whose page table is its lane's row
and whose live length is ``q_pos + 1`` — intra-chunk causality falls out
because the chunk's KV rows are written to their pages *before* the attend
(same order as the padded chunk step), and a token can never reach another
lane's rows because its table row only names its own lane's pages.  So the
same page-block online-softmax machinery (``ref.py`` off-TPU, the Pallas
scalar-prefetch kernel on TPU, grid ``(token, kv_head, page_slot)``) serves
both conventions; this module is the varlen entry point over it.

INT8 pools and sliding windows thread straight through: per-row dequant
scales ride the same per-token gather, and a window masks
``q_pos - row < window`` per token.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_reference


def varlen_positions(cu_seqlens, seq_lens) -> np.ndarray:
    """Per-token absolute positions of a packed stream → (T,) int32.

    ``cu_seqlens`` (S+1,) are lane boundaries in the stream; ``seq_lens``
    (S,) each lane's live KV length *after* this step's rows land.  Lane
    ``i``'s segment holds its final ``cu[i+1] - cu[i]`` positions, i.e.
    ``seq_lens[i] - n_i .. seq_lens[i] - 1`` — the packed restatement of the
    padded step's per-row bound ``kv_len - Lq + i``.
    """
    cu = np.asarray(cu_seqlens, np.int64)
    lens = np.asarray(seq_lens, np.int64)
    t = int(cu[-1])
    pos = np.zeros((t,), np.int32)
    for i in range(len(cu) - 1):
        n = int(cu[i + 1] - cu[i])
        pos[cu[i]:cu[i + 1]] = np.arange(lens[i] - n, lens[i], dtype=np.int32)
    return pos


def _as_4d(q: jax.Array) -> jax.Array:
    t, hq, d = q.shape
    return q.reshape(t, hq, 1, d)


def paged_attention_varlen(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           token_pages: jax.Array, q_pos: jax.Array, *,
                           cu_seqlens: Optional[Sequence[int]] = None,
                           scale: Optional[float] = None,
                           cap: Optional[float] = None,
                           window: Optional[int] = None,
                           exp_mode: str = "lut",
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           block_pages: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Ragged paged attention over a packed (T,)-token stream → (T, Hq, D).

    q: (T, Hq, D); k_pool/v_pool: (N, Hkv, page_size, D) with
    ``Hq % Hkv == 0`` (GQA); token_pages: (T, P) per-token page-table rows;
    q_pos: (T,) per-token absolute position / causal bound.  ``cu_seqlens``
    is accepted for callers that carry it (validation, debugging) — the
    computation depends only on the per-token arrays.  Dead rows (padding
    the stream to its bucket width) carry an all-scratch table row and
    ``q_pos = 0``; their output is garbage the caller never reads.

    Dispatch matches :func:`paged_attention`: Pallas kernel on TPU (grid
    over tokens), jnp page-block scan elsewhere; ``interpret=True`` forces
    the kernel in interpret mode.
    """
    del cu_seqlens                       # packing metadata, not compute input
    kv_len = jnp.asarray(q_pos, jnp.int32) + 1
    out = paged_attention(_as_4d(q), k_pool, v_pool, token_pages, kv_len,
                          scale=scale, cap=cap, window=window,
                          exp_mode=exp_mode, k_scale=k_scale, v_scale=v_scale,
                          block_pages=block_pages, interpret=interpret)
    return out[:, :, 0, :]


def paged_attention_varlen_reference(q: jax.Array, k_pool: jax.Array,
                                     v_pool: jax.Array,
                                     token_pages: jax.Array,
                                     q_pos: jax.Array, *,
                                     cu_seqlens: Optional[Sequence[int]] = None,
                                     scale: Optional[float] = None,
                                     cap: Optional[float] = None,
                                     window: Optional[int] = None,
                                     exp_mode: str = "lut",
                                     k_scale: Optional[jax.Array] = None,
                                     v_scale: Optional[jax.Array] = None,
                                     block_pages: Optional[int] = None
                                     ) -> jax.Array:
    """Pure-jnp varlen reference (the CPU/CI path), pinned explicitly —
    same batch=T reduction as :func:`paged_attention_varlen` but always the
    page-block scan, never the Pallas kernel."""
    del cu_seqlens
    kv_len = jnp.asarray(q_pos, jnp.int32) + 1
    out = paged_attention_reference(
        _as_4d(q), k_pool, v_pool, token_pages, kv_len, scale=scale, cap=cap,
        window=window, exp_mode=exp_mode, k_scale=k_scale, v_scale=v_scale,
        block_pages=block_pages)
    return out[:, :, 0, :]
