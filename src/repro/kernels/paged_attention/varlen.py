"""Varlen (ragged) paged attention: one packed token stream, no lane padding.

The serving step used to be a right-aligned ``(lanes, C)`` block — every
decode lane paid ``C`` rows of padding whenever any lane prefilled.  The
ragged step flattens the batch into one dense stream of ``T = Σ live
tokens`` rows:

    q            (T, Hq, D)     packed query rows, lane segments abutting
    token_pages  (T, P)         each token's *own* page-table row (its
                                lane's pages; dead/padding rows all-scratch)
    q_pos        (T,)           each token's absolute position — which is
                                also its causal bound: token t attends
                                pool rows at positions ``0 .. q_pos[t]``
    cu_seqlens   (S+1,)         lane boundaries (cumulative token counts);
                                with ``block_q > 1`` this is a real compute
                                input — it derives the q-block tiling below.

Two dataflows share this entry point:

**batch = T (untiled).**  The original identity: a packed token is exactly a
one-row lane whose page table is its lane's row and whose live length is
``q_pos + 1`` — paged decode at batch = T.  Correct, but a prefill chunk of
L tokens in one lane reads that lane's KV pages **L times** (once per
token-row of the grid).

**q-block tiled (``block_q = Bq > 1``, needs ``cu_seqlens``).**  The packed
stream is cut into q-blocks of up to ``Bq`` *contiguous same-lane* rows
(lane boundaries from ``cu_seqlens`` — a block never straddles a lane).
Each block becomes one lane of a ``(NB, Hq, Bq, D)`` chunked-prefill call:
its page-table row is the lane's row, its ``kv_len`` is
``q_pos[start] + Bq`` so kernel row ``i`` sits at position
``q_pos[start] + i`` — exactly the packed positions, because serving packs
each lane's chunk rows at contiguous ascending positions.  The grid becomes
``(q_block, kv_head, page_slot)`` and each KV page is read **once per
q-block instead of once per token** — ~Bq× less KV traffic on prefill
chunks.  Outputs scatter back to stream order through a token→slot map.
Block shapes (``block_q``, ``block_pages``, dequant granularity) are picked
by ``kernels/autotune.py`` against the ``perfmodel`` roofline.

Partial blocks carry dead tail rows (a lane whose chunk is not a multiple
of Bq): they compute finite garbage at positions past the lane's live end
and are never gathered back — same contract as the dead padding rows of the
stream itself.  Dead *stream* rows (bucket padding past ``cu[-1]``) must be
covered by a trailing pseudo-segment so ``cu[-1] == T`` (the scheduler does
this); their blocks also produce unread garbage.

INT8 pools and sliding windows thread straight through: per-row dequant
scales ride the same per-block gather, and a window masks
``q_pos - row < window`` per row.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_reference


def varlen_positions(cu_seqlens, seq_lens) -> np.ndarray:
    """Per-token absolute positions of a packed stream → (T,) int32.

    ``cu_seqlens`` (S+1,) are lane boundaries in the stream; ``seq_lens``
    (S,) each lane's live KV length *after* this step's rows land.  Lane
    ``i``'s segment holds its final ``cu[i+1] - cu[i]`` positions, i.e.
    ``seq_lens[i] - n_i .. seq_lens[i] - 1`` — the packed restatement of the
    padded step's per-row bound ``kv_len - Lq + i``.
    """
    cu = np.asarray(cu_seqlens, np.int64)
    lens = np.asarray(seq_lens, np.int64)
    t = int(cu[-1])
    pos = np.zeros((t,), np.int32)
    for i in range(len(cu) - 1):
        n = int(cu[i + 1] - cu[i])
        pos[cu[i]:cu[i + 1]] = np.arange(lens[i] - n, lens[i], dtype=np.int32)
    return pos


def validate_cu_seqlens(cu_seqlens, t: int) -> jax.Array:
    """Validate packed-stream lane boundaries against stream width ``t``.

    Shape checks always apply.  Value checks (``cu[0] == 0``, monotone
    non-decreasing, ``cu[-1] == t``) run eagerly on concrete inputs and
    raise ``ValueError`` so packing bugs fail loudly instead of producing
    garbage attention; traced values (inside jit) skip them — the serving
    step validates at pack time on the host copy.

    Dead padding rows (stream bucketed wider than the live tokens) must be
    *covered* by the boundaries — append a trailing pseudo-segment ending at
    ``t`` rather than stopping ``cu`` at the live width.
    """
    cu = jnp.asarray(cu_seqlens, jnp.int32)
    if cu.ndim != 1 or cu.shape[0] < 2:
        raise ValueError(
            f"cu_seqlens must be 1-D with >= 2 entries, got shape {cu.shape}")
    if not isinstance(cu, jax.core.Tracer):
        host = np.asarray(cu)
        if int(host[0]) != 0:
            raise ValueError(f"cu_seqlens must start at 0, got {host[0]}")
        if np.any(np.diff(host) < 0):
            raise ValueError(
                f"cu_seqlens must be non-decreasing, got {host.tolist()}")
        if int(host[-1]) != t:
            raise ValueError(
                f"cu_seqlens[-1] = {int(host[-1])} must equal the packed "
                f"stream width T = {t}; cover dead padding rows with a "
                f"trailing pseudo-segment instead of truncating")
    return cu


def q_block_layout(cu: jax.Array, q_pos: jax.Array, t: int, bq: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Cut the packed stream into q-blocks of ``bq`` same-lane rows.

    All shapes are static (``NB = t // bq + S`` is the worst-case block
    count: ``Σ ceil(n_i/bq) ≤ floor(Σ n_i / bq) + S``); which blocks are
    live is data.  Returns:

    - ``rows``   (NB, bq) stream row gathered into each block slot (dead
      slots clamped into range — they compute unread garbage);
    - ``start``  (NB,)    first stream row of each block (clamped), which
      carries the block's page-table row and base position;
    - ``kv_len`` (NB,)    per-block kernel length ``q_pos[start] + bq`` so
      kernel row ``i`` sits at position ``q_pos[start] + i`` (dead blocks
      pinned to 1 to bound their page walk);
    - ``slot``   (t,)     flattened block-output slot of each stream token
      (the inverse map: ``out[t] = block_out.reshape(-1, ...)[slot[t]]``).
    """
    s = cu.shape[0] - 1
    nb = t // bq + s
    n = cu[1:] - cu[:-1]
    nbi = (n + bq - 1) // bq
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(nbi).astype(jnp.int32)])
    blk = jnp.arange(nb, dtype=jnp.int32)
    lane = jnp.clip(jnp.searchsorted(off, blk, side="right") - 1, 0, s - 1)
    start = cu[lane] + (blk - off[lane]) * bq
    live = blk < off[-1]
    rows = start[:, None] + jnp.arange(bq, dtype=jnp.int32)[None, :]
    rows = jnp.clip(rows, 0, t - 1)
    start = jnp.clip(start, 0, t - 1)
    kv_len = jnp.where(live, q_pos[start] + bq, 1)
    tok = jnp.arange(t, dtype=jnp.int32)
    lane_t = jnp.clip(jnp.searchsorted(cu, tok, side="right") - 1, 0, s - 1)
    within = tok - cu[lane_t]
    slot = (off[lane_t] + within // bq) * bq + within % bq
    slot = jnp.clip(slot, 0, nb * bq - 1)
    return rows, start, kv_len, slot


def _as_4d(q: jax.Array) -> jax.Array:
    t, hq, d = q.shape
    return q.reshape(t, hq, 1, d)


def _tiled(q: jax.Array, token_pages: jax.Array, q_pos: jax.Array,
           cu: jax.Array, bq: int, attend) -> jax.Array:
    """Regather (T,)-stream → (NB, Hq, Bq, D) blocks, attend, scatter back."""
    t, hq, d = q.shape
    rows, start, kv_len, slot = q_block_layout(cu, q_pos, t, bq)
    qb = jnp.take(q, rows.reshape(-1), axis=0)       # (NB*bq, Hq, D)
    qb = qb.reshape(rows.shape[0], bq, hq, d)
    qb = jnp.moveaxis(qb, 1, 2)                      # (NB, Hq, bq, D)
    tbl = jnp.take(token_pages, start, axis=0)       # (NB, P)
    out = attend(qb, tbl, kv_len)                    # (NB, Hq, bq, Dv)
    flat = jnp.moveaxis(out, 2, 1).reshape(-1, hq, out.shape[-1])
    return jnp.take(flat, slot, axis=0)              # (T, Hq, Dv)


def paged_attention_varlen(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           token_pages: jax.Array, q_pos: jax.Array, *,
                           cu_seqlens: Optional[Sequence[int]] = None,
                           scale: Optional[float] = None,
                           cap: Optional[float] = None,
                           window: Optional[int] = None,
                           exp_mode: str = "lut",
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           block_q: Optional[int] = None,
                           block_pages: Optional[int] = None,
                           dequant: str = "block",
                           interpret: Optional[bool] = None) -> jax.Array:
    """Ragged paged attention over a packed (T,)-token stream → (T, Hq, D).

    q: (T, Hq, D); k_pool/v_pool: (N, Hkv, page_size, D) with
    ``Hq % Hkv == 0`` (GQA); token_pages: (T, P) per-token page-table rows;
    q_pos: (T,) per-token absolute position / causal bound.

    ``block_q = Bq > 1`` with ``cu_seqlens`` selects the q-block-tiled
    dataflow (module docstring): grid ``(q_block, kv_head, page_slot)``,
    each KV page read once per block instead of once per token.  Tiling
    additionally requires each lane's packed rows to sit at contiguous
    ascending positions (``q_pos[i+1] = q_pos[i] + 1`` within a lane) —
    the serving packing invariant.  ``block_q in (None, 1)`` or a missing
    ``cu_seqlens`` keeps the batch = T dataflow.  ``cu_seqlens``, when
    given, is validated (:func:`validate_cu_seqlens`) either way.

    Dispatch matches :func:`paged_attention`: Pallas kernel on TPU (the
    batch axis is tokens untiled, q-blocks tiled), jnp page-block scan
    elsewhere; ``interpret=True`` forces the kernel in interpret mode.
    ``dequant`` picks the int8 scale-application granularity in the scan
    ("block" | "page" — numerically identical, structurally different).
    """
    t = q.shape[0]
    cu = (validate_cu_seqlens(cu_seqlens, t)
          if cu_seqlens is not None else None)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    kw = dict(scale=scale, cap=cap, window=window, exp_mode=exp_mode,
              k_scale=k_scale, v_scale=v_scale, block_pages=block_pages,
              dequant=dequant, interpret=interpret)
    bq = None if block_q is None else int(min(block_q, max(t, 1)))
    if cu is not None and bq is not None and bq > 1:
        return _tiled(
            q, token_pages, q_pos, cu, bq,
            lambda qb, tbl, kv_len: paged_attention(
                qb, k_pool, v_pool, tbl, kv_len, **kw))
    kv_len = q_pos + 1
    out = paged_attention(_as_4d(q), k_pool, v_pool, token_pages, kv_len,
                          **kw)
    return out[:, :, 0, :]


def paged_attention_varlen_reference(q: jax.Array, k_pool: jax.Array,
                                     v_pool: jax.Array,
                                     token_pages: jax.Array,
                                     q_pos: jax.Array, *,
                                     cu_seqlens: Optional[Sequence[int]] = None,
                                     scale: Optional[float] = None,
                                     cap: Optional[float] = None,
                                     window: Optional[int] = None,
                                     exp_mode: str = "lut",
                                     k_scale: Optional[jax.Array] = None,
                                     v_scale: Optional[jax.Array] = None,
                                     block_q: Optional[int] = None,
                                     block_pages: Optional[int] = None,
                                     dequant: str = "block") -> jax.Array:
    """Pure-jnp varlen reference (the CPU/CI path), pinned explicitly —
    same reduction as :func:`paged_attention_varlen` (batch = T untiled,
    q-block tiled when ``block_q > 1`` and ``cu_seqlens`` is given) but
    always the page-block scan, never the Pallas kernel."""
    t = q.shape[0]
    cu = (validate_cu_seqlens(cu_seqlens, t)
          if cu_seqlens is not None else None)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    kw = dict(scale=scale, cap=cap, window=window, exp_mode=exp_mode,
              k_scale=k_scale, v_scale=v_scale, block_pages=block_pages,
              dequant=dequant)
    bq = None if block_q is None else int(min(block_q, max(t, 1)))
    if cu is not None and bq is not None and bq > 1:
        return _tiled(
            q, token_pages, q_pos, cu, bq,
            lambda qb, tbl, kv_len: paged_attention_reference(
                qb, k_pool, v_pool, tbl, kv_len, **kw))
    kv_len = q_pos + 1
    out = paged_attention_reference(
        _as_4d(q), k_pool, v_pool, token_pages, kv_len, **kw)
    return out[:, :, 0, :]
