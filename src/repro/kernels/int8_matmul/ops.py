"""Jit'd public wrapper for the int8 matmul Pallas kernel.

Quantises the activation dynamically (per-tensor absmax — the paper's DAC
input range), pads all dims to block multiples, runs the kernel, and strips
the padding.  Batched leading dims are folded into M.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize_dynamic
from repro.kernels.int8_matmul.kernel import int8_matmul_2d


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x, m0, m1):
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def int8_matmul(x: jax.Array, wq: QTensor, *, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """x (…, K) float × wq (K, N) int8 QTensor → (…, N) f32."""
    if interpret is None:
        interpret = _use_interpret()
    *lead, kk = x.shape
    n = wq.values.shape[1]
    m = 1
    for s in lead:
        m *= s

    xq = quantize_dynamic(x)
    bm = max(8, min(block_m, m))
    bn = max(128, min(block_n, n))
    bk = max(128, min(block_k, kk))
    xp = _pad2(xq.values.reshape(m, kk), bm, bk)
    wp = _pad2(wq.values, bk, bn)
    ws = jnp.pad(wq.scale.reshape(1, n), ((0, 0), (0, (-n) % bn)))

    out = int8_matmul_2d(xp, wp, xq.scale.reshape(1, 1), ws,
                         block_m=bm, block_n=bn, block_k=bk,
                         interpret=interpret)
    return out[:m, :n].reshape(*lead, n)
