"""Pallas TPU kernel: tiled int8×int8→int32 matmul (paper §V — INT8 CIM).

The CIM crossbar computes 8-bit MVMs with analog accumulation; the TPU
analogue is the MXU's native int8 path (2× bf16 throughput on v5e).  The
kernel is a classic three-axis tiling

    grid = (M/bm, N/bn, K/bk)          k innermost, sequential

with an int32 VMEM accumulator that persists across the k steps of one
(m, n) tile; on the last k step both quantisation scales (per-tensor input
scale, per-output-channel weight scale — the paper's DAC input range and
per-column crossbar conductance scale) are applied and the f32 tile stored.

Default tiles bm = bn = 256, bk = 512: operands 256×512 + 512×256 int8
(256 KiB) + 256×256 int32 accumulator (256 KiB) — comfortably in VMEM and
every matmul dim is a multiple of the 128-wide MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def int8_matmul_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
                       num_k_blocks: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == num_k_blocks - 1)
    def _emit():
        scale = xs_ref[0, 0] * ws_ref[...]                    # (1, bn)
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def int8_matmul_2d(x: jax.Array, w: jax.Array, x_scale: jax.Array,
                   w_scale: jax.Array, *, block_m: int = 256,
                   block_n: int = 256, block_k: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x (M, K) int8 × w (K, N) int8 → (M, N) f32, scales applied.

    M/N/K must be multiples of the block sizes (ops.py pads).
    x_scale: (1, 1) f32 per-tensor; w_scale: (1, N) f32 per-channel.
    """
    m, kk = x.shape
    _, n = w.shape
    assert m % block_m == 0 and n % block_n == 0 and kk % block_k == 0
    nk = kk // block_k
    kernel = functools.partial(int8_matmul_kernel, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x, w, x_scale, w_scale)
