"""Pure-jnp oracle for the int8 matmul kernel — the core quant path."""
from __future__ import annotations

import jax

from repro.core.quant import QTensor, int8_matmul as _core_int8_matmul


def int8_matmul_ref(x: jax.Array, wq: QTensor) -> jax.Array:
    """x (…, K) float × wq (K, N) QTensor → (…, N) f32."""
    return _core_int8_matmul(x, wq)
