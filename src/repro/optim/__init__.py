from repro.optim.adamw import (AdamWConfig, AdamWState, accumulated_grads,
                               adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule, global_norm)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "cosine_schedule", "global_norm",
           "accumulated_grads"]
