"""AdamW + schedules + clipping + gradient accumulation (pure JAX pytrees).

Self-contained (no optax): the optimizer state mirrors the param pytree, so
the sharding rules in ``parallel/sharding.py`` apply leaf-for-leaf and the
checkpoint layer stores it like any other tree.

``moment_dtype="bfloat16"`` halves optimizer memory (the ZeRO-style trick
that lets grok-1-314b train on 256 chips — DESIGN.md §4); error introduced
is bounded by bf16's 8 mantissa bits on the *moments*, not the weights.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    m: Params                # first moment (param-shaped tree)
    v: Params                # second moment


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    moment_dtype: str = "float32"        # float32 | bfloat16


def adamw_init(params: Params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 cfg: AdamWConfig, lr: Optional[jax.Array] = None,
                 scan_subtree: Optional[Tuple[str, ...]] = None
                 ) -> Tuple[Params, AdamWState, dict]:
    """One AdamW step.  ``lr`` overrides cfg.lr (schedules).

    ``scan_subtree`` names a nested-dict path (e.g. ("trunk", "periods"))
    whose leaves are stacked along dim 0 (scan-over-layers params).  The
    update for that subtree is *streamed* with lax.scan over dim 0, so the
    f32 temporaries are per-layer-slice instead of whole-stack — at
    grok-1 scale that is ~25 MB instead of ~1.5 GiB per leaf (DESIGN.md §4).
    """
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    lr = cfg.lr if lr is None else lr
    step = state.step + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd_tree(p_t, g_t, m_t, v_t):
        new_m = jax.tree.map(
            lambda g, m: (cfg.b1 * m.astype(jnp.float32)
                          + (1 - cfg.b1) * g.astype(jnp.float32)
                          ).astype(m.dtype), g_t, m_t)
        new_v = jax.tree.map(
            lambda g, v: (cfg.b2 * v.astype(jnp.float32)
                          + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(v.dtype), g_t, v_t)

        def upd(p, m, v):
            mh = m.astype(jnp.float32) / c1
            vh = v.astype(jnp.float32) / c2
            delta = (mh / (jnp.sqrt(vh) + cfg.eps)
                     + cfg.weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        return jax.tree.map(upd, p_t, new_m, new_v), new_m, new_v

    def get(tree, path):
        for k in path:
            tree = tree[k]
        return tree

    def with_replaced(tree, path, value):
        if not path:
            return value
        out = dict(tree)
        out[path[0]] = with_replaced(tree[path[0]], path[1:], value)
        return out

    has_sub = scan_subtree is not None
    if has_sub:
        try:
            sub_p = get(params, scan_subtree)
        except (KeyError, TypeError):
            has_sub = False

    if has_sub:
        sub_g = get(grads, scan_subtree)
        sub_m = get(state.m, scan_subtree)
        sub_v = get(state.v, scan_subtree)

        def body(_, slices):
            ps, gs, ms, vs = slices
            return None, upd_tree(ps, gs, ms, vs)

        _, (s_p, s_m, s_v) = jax.lax.scan(body, None,
                                          (sub_p, sub_g, sub_m, sub_v))
        # the (small) remainder of the tree updates whole-leaf
        none = object()
        rest = lambda t: with_replaced(t, scan_subtree, {})
        r_p, r_m, r_v = upd_tree(rest(params), rest(grads), rest(state.m),
                                 rest(state.v))
        new_p = with_replaced(r_p, scan_subtree, s_p)
        new_m = with_replaced(r_m, scan_subtree, s_m)
        new_v = with_replaced(r_v, scan_subtree, s_v)
    else:
        new_p, new_m, new_v = upd_tree(params, grads, state.m, state.v)
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return sched


# --------------------------------------------------------------------------
# gradient accumulation
# --------------------------------------------------------------------------

def accumulated_grads(loss_fn: Callable, params: Params, batch: Any,
                      microbatches: int, accum_dtype: str = "float32"
                      ) -> Tuple[jax.Array, Params, Any]:
    """Split ``batch`` dim0 into ``microbatches`` and mean loss+grads via scan.

    Peak activation memory drops by ~microbatches× (HASTILY's pipeline-fill
    trade-off in TPU form — DESIGN.md §2).  ``accum_dtype="bfloat16"`` halves
    the resident accumulator — used for the largest models where the f32
    accumulator tree alone exceeds HBM headroom; the loss is scaled by
    1/microbatches *inside* the sum to keep magnitudes in bf16 range.
    """
    if microbatches <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, grads, aux

    acc_dt = jnp.dtype(accum_dtype)
    inv = 1.0 / microbatches

    def reshape(x):
        b = x.shape[0]
        assert b % microbatches == 0, (b, microbatches)
        return x.reshape((microbatches, b // microbatches) + x.shape[1:])

    mb = jax.tree.map(reshape, batch)

    def body(carry, mbatch):
        loss_acc, grads_acc = carry
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch)
        grads_acc = jax.tree.map(
            lambda a, g: a + (g.astype(jnp.float32) * inv).astype(a.dtype),
            grads_acc, grads)
        return (loss_acc + loss, grads_acc), aux

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (loss_sum, grads_sum), auxs = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_g), mb)
    # grads stay in accum dtype; consumers (adamw/compress) upcast per leaf.
    aux = jax.tree.map(lambda a: a[-1], auxs)
    return loss_sum * inv, grads_sum, aux
