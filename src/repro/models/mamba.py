"""Mamba-1 selective SSM block (falcon-mamba-7b).

The recurrence ``h_t = Ā_t h_{t-1} + B̄_t x_t`` *is* the limit case of
HASTILY's fine-grained pipeline: O(1) state streamed over the sequence, no
quadratic intermediate by construction (DESIGN.md §6).  We implement it with
the same associative-combine machinery that legalises the paper's online
softmax: pairs ``(a, b)`` combine as ``(a₂a₁, a₂b₁ + b₂)`` under
``jax.lax.associative_scan``, chunked over the sequence so the materialised
state is O(chunk · d_inner · n) instead of O(L · d_inner · n).

The discretisation ``Ā = exp(Δ ⊗ A)`` uses the HASTILY LUT exponential
(``cfg.exp_mode``) — the technique's non-attention reuse point.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.streaming_attention import _EXP_FNS
from repro.models.layers import _dtype, dense_init, dense_apply
from repro.parallel.ctx import maybe_shard

Params = Dict[str, Any]


def mamba_init(key, cfg: ModelConfig) -> Params:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    # S4D-real initialisation: A_log = log(1..n) per channel.
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype=dt),
        "dt_proj": dense_init(ks[3], r, di, dtype=dt, scale=r ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))).astype(dt),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype=dt),
    }


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _chunked_ssm(exp_fn, a, dt_proj, dt_bias, dt_low, bmat, cmat, xf, h0,
                 chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan with *per-chunk* discretisation.

    The (B, L, di, n) tensors ``Ā = exp(Δ⊗A)`` and ``B̄x`` are never
    materialised over the full L — each chunk computes its own inside the
    scan body (O(chunk·di·n) transient instead of O(L·di·n); the same
    never-materialise discipline as the streaming-attention kernel).

    dt_low: (B, L, r); bmat/cmat: (B, L, n); xf: (B, L, di) f32;
    a: (di, n) < 0; h0: (B, di, n).  Returns (y (B, L, di), h_last).
    """
    b, l, r = dt_low.shape
    di, n = a.shape
    pad = (-l) % chunk
    if pad:
        dt_low = jnp.pad(dt_low, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    nc = (l + pad) // chunk
    # Padded steps must be the identity element (aa=1, bx=0) so h_last — the
    # streaming carry — is untouched by padding.
    valid = (jnp.arange(nc * chunk) < l).reshape(nc, chunk)

    def cview(t):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    xs = (cview(dt_low), cview(bmat), cview(cmat), cview(xf), valid)

    def body(h, inp):
        dtl_c, b_c, c_c, x_c, v_c = inp                           # (B, ch, ·)
        dt = jax.nn.softplus(
            dense_apply(dt_proj, dtl_c).astype(jnp.float32)
            + dt_bias.astype(jnp.float32))                        # (B, ch, di)
        v = v_c[None, :, None, None]
        aa = jnp.where(v, exp_fn(dt[..., None] * a[None, None]), 1.0)
        bx = jnp.where(v, (dt * x_c)[..., None] * b_c[:, :, None, :], 0.0)
        a_cum, b_cum = jax.lax.associative_scan(_combine, (aa, bx), axis=1)
        h_all = a_cum * h[:, None] + b_cum                        # (B,ch,di,n)
        y_c = jnp.einsum("bldn,bln->bld", h_all, c_c)
        return h_all[:, -1], y_c

    # Without the inner checkpoint, the scan's backward saves each chunk's
    # (B, chunk, di, n) intermediates for ALL chunks at once (tens of GiB at
    # 7B/4k) — remat trades that for one recompute per chunk.
    h_last, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, di)
    return y[:, :l], h_last


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B, L, di); w: (K, di).  ``state`` is the
    trailing K-1 inputs from the previous call (decode).  Returns (y, new state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y + b.astype(x.dtype), new_state


def mamba_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
                cache: Optional[Params] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (B, L, D) → (B, L, D).  ``cache``: {"conv", "h"} streaming state."""
    exp_fn = _EXP_FNS[cfg.exp_mode]
    b, l, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = dense_apply(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    # d_inner is elementwise through the whole recurrence — shard it over
    # the model axis so the (B, chunk, di, n) scan tensors divide mesh-wide.
    xs = maybe_shard(xs, ("dp", None, "tp"))
    z = maybe_shard(z, ("dp", None, "tp"))
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"],
                                  cache["conv"] if cache else None)
    xs = jax.nn.silu(xs)

    proj = dense_apply(p["x_proj"], xs).astype(jnp.float32)
    dt_low, bmat, cmat = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    a = -jnp.exp(p["A_log"])                                      # (di, n) < 0
    xf = xs.astype(jnp.float32)

    h0 = (cache["h"].astype(jnp.float32) if cache
          else jnp.zeros((b, di, n), jnp.float32))
    if l == 1:  # decode fast path: one recurrence step, no scan
        dt = jax.nn.softplus(
            dense_apply(p["dt_proj"], dt_low.astype(x.dtype)
                        ).astype(jnp.float32)
            + p["dt_bias"].astype(jnp.float32))                   # (B, 1, di)
        aa = exp_fn(dt[..., None] * a[None, None])
        bx = (dt * xf)[..., None] * bmat[:, :, None, :]
        h_last = aa[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h_last, cmat[:, 0])[:, None]
    else:
        y, h_last = _chunked_ssm(exp_fn, a, p["dt_proj"], p["dt_bias"],
                                 dt_low.astype(x.dtype), bmat, cmat, xf, h0,
                                 cfg.ssm_chunk)
    y = y + p["D"] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = dense_apply(p["out_proj"], y)
    new_cache = ({"conv": conv_state, "h": h_last.astype(jnp.float32)}
                 if cache is not None else None)
    return out, new_cache


def mamba_cache_init(cfg: ModelConfig, batch: int) -> Params:
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                              _dtype(cfg)),
            "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}
