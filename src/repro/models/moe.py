"""Token-choice top-k Mixture-of-Experts layer (granite-moe, grok-1).

GShard/Switch-style capacity-bucketed dispatch expressed as einsums so GSPMD
can lower the dispatch/combine to all-to-alls when the expert dimension is
sharded.  The router softmax uses the HASTILY LUT exponential — the paper's
technique applies to *every* softmax in the model, not just attention.

Dispatch algebra (T tokens, E experts, C capacity per expert, k experts/token):
  gates           = top-k( lut_softmax(x @ Wr) )                (T, E) sparse
  dispatch[t,e,c] = 1 iff token t is slot c of expert e         (T, E, C)
  expert_in       = einsum('tec,td->ecd', dispatch, x)          (E, C, D)
  expert_out      = FFN_e(expert_in)   (batched over E)         (E, C, D)
  y               = einsum('tec,ecd->td', dispatch*gate, out)   (T, D)

Tokens overflowing an expert's capacity are dropped (standard; the residual
connection carries them).  FLOPs are E·C·ffn = capacity_factor × the useful
top-k FLOPs — recorded in the roofline's MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

import os

from repro.configs.base import ModelConfig
from repro.core.lut_softmax import lut_softmax
from repro.core.streaming_attention import _EXP_FNS
from repro.models.layers import _ACTS, _dtype, dense_init
from repro.parallel.ctx import maybe_shard

Params = Dict[str, Any]


def _einsum32(eq: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """bf16×bf16→f32 einsum.  The TPU MXU does this natively; the CPU dot
    thunk cannot *execute* it (fine for dry-run lowering, which never runs),
    so pure-CPU execution upcasts.  REPRO_TARGET_TPU=1 (set by dryrun.py)
    keeps the TPU-native form in the lowered HLO."""
    if (jax.default_backend() == "cpu"
            and os.environ.get("REPRO_TARGET_TPU", "0") != "1"):
        return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
    return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Per-expert capacity: cf · k · T / E, rounded up to a multiple of 8."""
    c = cfg.moe_capacity_factor * cfg.experts_per_token * n_tokens / cfg.num_experts
    return max(8, int(-(-c // 8) * 8))


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)

    def stack(k, d_in, d_out):
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32)
                * d_in ** -0.5).astype(dt)

    p = {"router": dense_init(ks[0], d, e, dtype=jnp.float32),
         "up": stack(ks[1], d, f), "down": stack(ks[2], f, d)}
    if cfg.mlp_gated:
        p["gate"] = stack(ks[3], d, f)
    return p


def _topk_dispatch(cfg: ModelConfig, probs: jax.Array, capacity: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """probs (T, E) → (dispatch (T,E,C) bool, combine (T,E,C) f32)."""
    t, e = probs.shape
    k = cfg.experts_per_token
    remaining = probs
    slot_of = []   # per choice: (T, E) one-hot of chosen expert
    gate_of = []
    for _ in range(k):  # iterative top-1 (k is small and static)
        choice = jnp.argmax(remaining, axis=-1)                    # (T,)
        onehot = jax.nn.one_hot(choice, e, dtype=probs.dtype)      # (T, E)
        gate_of.append(jnp.sum(remaining * onehot, axis=-1))       # (T,)
        slot_of.append(onehot)
        remaining = remaining * (1.0 - onehot)
    dispatch = jnp.zeros((t, e, capacity), jnp.float32)
    combine = jnp.zeros((t, e, capacity), jnp.float32)
    # Slot assignment: position within expert = running count of earlier
    # (choice-round, token) pairs routed to that expert.
    prior = jnp.zeros((e,), jnp.int32)
    for onehot, gate in zip(slot_of, gate_of):
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot + prior[None, :]  # (T,E)
        prior = prior + jnp.sum(onehot, axis=0).astype(jnp.int32)
        slot = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)     # (T,)
        keep = (slot < capacity)
        slot = jnp.clip(slot, 0, capacity - 1)
        sl_onehot = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)
        d_k = onehot[..., None] * sl_onehot[:, None, :] * keep[:, None, None]
        dispatch = dispatch + d_k
        combine = combine + d_k * gate[:, None, None]
    return dispatch, combine


def _group_size(cfg: ModelConfig, t: int) -> int:
    """Largest divisor of t not exceeding cfg.moe_group."""
    g = min(cfg.moe_group, t)
    while t % g:
        g -= 1
    return g


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B, L, D) → (y (B, L, D), aux_loss scalar).

    Tokens are split into GShard-style *groups* of ≤ ``cfg.moe_group``;
    routing/capacity is per-group, so the (t, E, C) dispatch tensor is
    O(T · cf · k · t_g) total — linear in T, not quadratic (C would otherwise
    grow with T).  Groups also shard cleanly over the dp axis.
    """
    b, l, d = x.shape
    t = b * l
    tg = _group_size(cfg, t)
    g = t // tg
    # Groups stay dp-sharded through dispatch→FFN→combine; without explicit
    # constraints SPMD picks a 128-way group sharding for the dispatch einsum
    # and then fully rematerialises per layer ("involuntary full remat").
    _g = lambda a: maybe_shard(a, ("dp",) + (None,) * (a.ndim - 1))
    xt = _g(x.reshape(g, tg, d))
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"]["w"])
    probs = lut_softmax(logits, exp_fn=_EXP_FNS[cfg.exp_mode])
    capacity = moe_capacity(cfg, tg)
    dispatch, combine = jax.vmap(
        lambda pr: _topk_dispatch(cfg, pr, capacity))(probs)   # (G,t,E,C) ×2
    # Renormalise combine weights over the selected experts (top-k convention).
    denom = jnp.sum(combine, axis=(2, 3), keepdims=True)
    combine = _g(combine / jnp.maximum(denom, 1e-9))
    dispatch = _g(dispatch)

    expert_in = _g(_einsum32("gtec,gtd->gecd", dispatch,
                             xt.astype(jnp.float32)).astype(x.dtype))
    act = _ACTS[cfg.act]
    h = _einsum32("gecd,edf->gecf", expert_in, p["up"]).astype(x.dtype)
    if cfg.mlp_gated:
        gate = _einsum32("gecd,edf->gecf", expert_in,
                         p["gate"]).astype(x.dtype)
        h = act(gate) * h
    else:
        h = act(h)
    h = maybe_shard(h, ("dp", None, None, "tp"))
    expert_out = _g(_einsum32("gecf,efd->gecd", h, p["down"]))
    y = _g(_einsum32("gtec,gecd->gtd", combine, expert_out)).astype(x.dtype)

    # Load-balancing auxiliary loss (Switch eq. 4): E · Σ_e f_e · P_e.
    frac_routed = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))   # (E,)
    frac_prob = jnp.mean(probs, axis=(0, 1))                          # (E,)
    aux = cfg.num_experts * jnp.sum(frac_routed * frac_prob) / cfg.experts_per_token
    return y.reshape(b, l, d), aux
