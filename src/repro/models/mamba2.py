"""Mamba-2 (SSD) block — the state-mixer of zamba2-1.2b.

Mamba-2 restricts the decay to a *scalar per head*, which turns the chunked
recurrence into the "state-space dual" matrix form: within a chunk it is an
attention-like masked matmul C·(decay mask)·Bᵀ·X — i.e. *exactly* the
structure HASTILY pipelines (logits → weighting → value matmul) with the
softmax replaced by a decay kernel — and across chunks it is the same
associative state carry as Mamba-1.  All decay exponentials go through the
HASTILY LUT exp (inputs are ≤ 0, the LUT's accurate range).

Shapes: heads H = d_inner / ssm_head_dim (P), state N = ssm_state,
groups G (B/C shared across H/G heads, GQA-style).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.streaming_attention import _EXP_FNS
from repro.models.layers import _dtype, dense_init, dense_apply
from repro.parallel.ctx import maybe_shard

Params = Dict[str, Any]


def mamba2_heads(cfg: ModelConfig) -> int:
    return cfg.d_inner // cfg.ssm_head_dim


def mamba2_init(key, cfg: ModelConfig) -> Params:
    d, di, n, g = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    h = mamba2_heads(cfg)
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * g * n + h, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * g * n),
                                     jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((di + 2 * g * n,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dt),     # gated RMSNorm before out_proj
        "out_proj": dense_init(ks[2], di, d, dtype=dt),
    }


def _gated_rmsnorm(scale: jax.Array, y: jax.Array, z: jax.Array) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    return y * (1.0 + scale.astype(jnp.float32))


def _ssd_chunked(exp_fn, log_a, bmat, cmat, xdt, s0, chunk: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  log_a: (B,L,H) ≤ 0; bmat/cmat: (B,L,H,N); xdt: (B,L,H,P);
    s0: (B,H,N,P).  Returns (y (B,L,H,P), final state)."""
    b, l, h = log_a.shape
    n, p = bmat.shape[-1], xdt.shape[-1]
    pad = (-l) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // chunk

    def cview(t, extra):  # (B, L, ...) → (nc, B, chunk, ...)
        return t.reshape((b, nc, chunk) + extra).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(extra))))

    log_a = cview(log_a, (h,))
    bmat, cmat = cview(bmat, (h, n)), cview(cmat, (h, n))
    xdt = cview(xdt, (h, p))

    def body(s, inputs):
        la, bc, cc, xc = inputs                  # (B, chunk, H, ...)
        s_cum = jnp.cumsum(la, axis=1)           # (B, chunk, H) cumulative log-decay
        # intra-chunk: G_ij = (C_i·B_j)·exp(s_i − s_j) for j ≤ i
        scores = jnp.einsum("bihn,bjhn->bhij", cc, bc,
                            preferred_element_type=jnp.float32)
        decay = s_cum[:, :, None] - s_cum[:, None, :]       # (B, i, j, H)
        decay = jnp.transpose(decay, (0, 3, 1, 2))
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        gmat = jnp.where(causal, scores * exp_fn(jnp.minimum(decay, 0.0)), 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", gmat, xc,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bihn,bhnp->bihp", cc * exp_fn(s_cum)[..., None], s,
                             preferred_element_type=jnp.float32)
        # state update: S' = exp(Σ la)·S + Σ_j exp(s_end − s_j) B_j xdt_jᵀ
        tail = exp_fn(s_cum[:, -1:] - s_cum)                # (B, chunk, H)
        s_new = (exp_fn(s_cum[:, -1])[..., None, None] * s
                 + jnp.einsum("bjhn,bjhp->bhnp", bc * tail[..., None], xc,
                              preferred_element_type=jnp.float32))
        return s_new, y_intra + y_inter

    # Inner remat: see mamba.py — keeps the backward from saving every
    # chunk's (B, chunk, chunk, H) score tensors simultaneously.
    s_last, y = jax.lax.scan(jax.checkpoint(body), s0,
                             (log_a, bmat, cmat, xdt))
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, p)
    return y[:, :l], s_last


def mamba2_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
                 cache: Optional[Params] = None
                 ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (B, L, D) → (B, L, D).  cache: {"conv", "S"}."""
    from repro.models.mamba import _causal_conv  # shared depthwise conv
    exp_fn = _EXP_FNS[cfg.exp_mode]
    b, l, _ = x.shape
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    h, pdim = mamba2_heads(cfg), cfg.ssm_head_dim

    zxbcdt = dense_apply(p["in_proj"], x)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   cache["conv"] if cache else None)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                          # (B,L,H)
    a = -jnp.exp(p["A_log"])                                      # (H,) < 0
    log_a = dt * a[None, None]                                    # (B,L,H) ≤ 0

    # SSD heads are independent — shard them over the model axis so the
    # per-chunk (B, chunk, chunk, H) score tensors divide mesh-wide.
    xh = maybe_shard(xs.astype(jnp.float32).reshape(b, l, h, pdim),
                     ("dp", None, "tp", None))
    xdt = xh * dt[..., None]
    rep = h // g
    bh = maybe_shard(jnp.repeat(bmat.astype(jnp.float32).reshape(b, l, g, n),
                                rep, axis=2), ("dp", None, "tp", None))
    ch = maybe_shard(jnp.repeat(cmat.astype(jnp.float32).reshape(b, l, g, n),
                                rep, axis=2), ("dp", None, "tp", None))

    s0 = (cache["S"].astype(jnp.float32) if cache
          else jnp.zeros((b, h, n, pdim), jnp.float32))
    if l == 1:  # decode: single recurrence step
        a_step = exp_fn(log_a[:, 0])                              # (B,H)
        s_last = (a_step[..., None, None] * s0
                  + jnp.einsum("bhn,bhp->bhnp", bh[:, 0], xdt[:, 0]))
        y = jnp.einsum("bhn,bhnp->bhp", ch[:, 0], s_last)[:, None]
    else:
        y, s_last = _ssd_chunked(exp_fn, log_a, bh, ch, xdt, s0, cfg.ssm_chunk)

    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, l, di)
    y = _gated_rmsnorm(p["norm_scale"], y, z).astype(x.dtype)
    out = dense_apply(p["out_proj"], y)
    new_cache = ({"conv": conv_state, "S": s_last.astype(jnp.float32)}
                 if cache is not None else None)
    return out, new_cache


def mamba2_cache_init(cfg: ModelConfig, batch: int) -> Params:
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * g * n),
                              _dtype(cfg)),
            "S": jnp.zeros((batch, mamba2_heads(cfg), n, cfg.ssm_head_dim),
                           jnp.float32)}
