"""Uniform model API: ``build_model(cfg)`` → init / loss / prefill / decode.

Every family exposes the same four entry points so the launcher, trainer,
serving engine, dry-run, and benchmarks are family-agnostic.  ``input_specs``
produces ShapeDtypeStruct stand-ins for every input of a given step kind —
the dry-run lowers against these (no allocation).

Step kinds (assignment shape cells):
  train    → loss+grad over (tokens, labels)            [train_4k]
  prefill  → fill KV/SSM caches for a full sequence     [prefill_32k]
  decode   → one new token against a length-L cache     [decode_32k, long_500k]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention_api import backend_for_config, get_backend
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.models.lm import cross_entropy

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, Dict[str, jax.Array]], Tuple[jax.Array, Dict]]
    init_cache: Callable[..., Params]
    prefill: Optional[Callable] = None       # (params, batch, caches) → (logits, state)
    decode_step: Optional[Callable] = None   # (params, token, state, index) → (logits, state)
    # (params, tokens (B, C), pools, page_table (B, P), kv_len (B,),
    # q_len (B,)) → (last-row logits (B, V), pools): one unified serving
    # step — right-aligned chunked prefill, decode (C == 1) and idle lanes
    # mixed in one batch, KV rows written in place through the table
    # (EngineCore.step's workhorse; there is no separate paged decode entry)
    prefill_chunk_paged: Optional[Callable] = None
    # (params, tokens (T,), pools, token_pages (T, P), pos (T,),
    # last_idx (lanes,) or (lanes, 1+k)) → (logits (lanes[, 1+k], V),
    # pools): the token-level ragged serving step — one packed stream of
    # T = Σ live tokens, no (lanes, C) padding (EngineCore mode="ragged"'s
    # workhorse; the 2-D last_idx form is the speculative verify step,
    # extracting every drafted position's logits from the same stream)
    step_ragged: Optional[Callable] = None


# --------------------------------------------------------------------------
# family wiring
# --------------------------------------------------------------------------

def _bert_loss(cfg, params, batch):
    logits, _, aux = LM.lm_apply(cfg, params, batch["tokens"], causal=False)
    ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": aux}


def _bert_encode(cfg, params, batch, caches=None):
    logits, _, _ = LM.lm_apply(cfg, params, batch["tokens"], causal=False)
    return logits, caches


def _lm_loss_with_labels(cfg, params, batch):
    if "labels" in batch and batch["labels"].shape == batch["tokens"].shape:
        prefix = batch.get("prefix_embed")
        logits, _, aux = LM.lm_apply(cfg, params, batch["tokens"],
                                     prefix_embed=prefix)
        lp = 0 if prefix is None else prefix.shape[1]
        ce = cross_entropy(logits[:, lp:], batch["labels"],
                           batch.get("loss_mask"))
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}
    return LM.lm_loss(cfg, params, batch)


def _lm_prefill(cfg, params, batch, caches):
    return LM.lm_prefill(cfg, params, batch["tokens"], caches,
                         prefix_embed=batch.get("prefix_embed"))


def _encdec_prefill(cfg, params, batch, caches):
    self_c = caches["self"] if "self" in caches else caches
    logits, new_c, ckv = ED.encdec_prefill(cfg, params, batch["frames"],
                                           batch["tokens"], self_c)
    return logits, {"self": new_c, "cross": ckv}


def _encdec_decode(cfg, params, token, state, index):
    logits, caches = ED.encdec_decode_step(cfg, params, token, state["self"],
                                           state["cross"], index)
    return logits, {"self": caches, "cross": state["cross"]}


def build_model(cfg: ModelConfig) -> Model:
    # Fail fast on a mistyped backend name here rather than deep inside a
    # jitted trace (resolution itself is per-call; "auto" always resolves).
    name = backend_for_config(cfg.attn_backend, cfg.attn_impl)
    if name != "auto":
        get_backend(name)
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=functools.partial(ED.encdec_init, cfg=cfg),
            loss=functools.partial(ED.encdec_loss, cfg),
            init_cache=functools.partial(ED.encdec_cache_init, cfg),
            prefill=functools.partial(_encdec_prefill, cfg),
            decode_step=functools.partial(_encdec_decode, cfg),
        )
    if cfg.family == "bert":
        return Model(
            cfg=cfg,
            init=functools.partial(LM.lm_init, cfg=cfg),
            loss=functools.partial(_bert_loss, cfg),
            init_cache=functools.partial(LM.trunk_cache_init, cfg),
            prefill=functools.partial(_bert_encode, cfg),
            decode_step=None,   # encoder-only: no decode step (assignment)
        )
    return Model(
        cfg=cfg,
        init=functools.partial(LM.lm_init, cfg=cfg),
        loss=functools.partial(_lm_loss_with_labels, cfg),
        init_cache=functools.partial(LM.trunk_cache_init, cfg),
        prefill=functools.partial(_lm_prefill, cfg),
        decode_step=functools.partial(
            lambda cfg, params, token, state, index:
            LM.lm_decode_step(cfg, params, token, state, index), cfg),
        prefill_chunk_paged=functools.partial(LM.lm_prefill_chunk_paged, cfg),
        step_ragged=functools.partial(LM.lm_step_ragged, cfg),
    )


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    m = build_model(cfg)
    if cfg.family == "encdec":
        return ED.encdec_init(jax.random.PRNGKey(seed), cfg)
    return LM.lm_init(jax.random.PRNGKey(seed), cfg)


# --------------------------------------------------------------------------
# ShapeDtypeStruct input specs (dry-run; no allocation)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int,
                with_labels: bool = True) -> Dict[str, Any]:
    """Training/prefill batch stand-ins, incl. modality-frontend stubs."""
    specs: Dict[str, Any] = {"tokens": _sds((batch, seq), jnp.int32)}
    if with_labels:
        specs["labels"] = _sds((batch, seq), jnp.int32)
    if cfg.family == "encdec":
        specs["frames"] = _sds((batch, cfg.frontend_len, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "vlm":
        specs["prefix_embed"] = _sds((batch, cfg.frontend_len, cfg.d_model),
                                     jnp.bfloat16)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    model = build_model(cfg)
    specs = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    if cfg.family == "encdec":
        params = jax.eval_shape(
            lambda: ED.encdec_init(jax.random.PRNGKey(0), cfg))
        enc = _sds((batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        ckv = jax.eval_shape(
            lambda p, e: ED.cross_kvs_init(cfg, p, e), params, enc)
        return {"self": specs, "cross": ckv}
    return specs


def input_specs(cfg: ModelConfig, kind: str, seq: int, batch: int
                ) -> Dict[str, Any]:
    """All inputs (except params/opt-state) of the step function for ``kind``."""
    # vlm caches also hold the modality prefix rows
    cache_len = seq + (cfg.frontend_len if cfg.family == "vlm" else 0)
    if kind == "train":
        return {"batch": batch_specs(cfg, batch, seq)}
    if kind == "prefill":
        return {"batch": batch_specs(cfg, batch, seq, with_labels=False),
                "caches": cache_specs(cfg, batch, cache_len)}
    if kind == "decode":
        return {"token": _sds((batch,), jnp.int32),
                "state": cache_specs(cfg, batch, cache_len),
                "index": _sds((), jnp.int32)}
    raise ValueError(f"unknown step kind {kind!r}")
