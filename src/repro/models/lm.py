"""Decoder-LM assembly for the dense / moe / ssm / hybrid / vlm families.

A model is a stack of *periods*: one period = one cycle of ``cfg.pattern``
(e.g. gemma3's 5×local+1×global) or, for the zamba2 hybrid, ``hybrid_period``
Mamba-2 blocks preceded by the *shared* attention block (weights reused every
period — only its KV cache is per-period).  Periods are homogeneous, so the
trunk is a ``lax.scan`` over stacked period params: compile time and HLO size
stay O(period), remat applies per period, and the dry-run scales to 64-layer
configs.  Layers that don't fill a whole period form an unrolled tail.

All functions are pure; caches are explicit pytrees threaded in and out.
Attention inside every layer dispatches through the backend registry
(``core/attention_api``) keyed by ``cfg.attn_backend`` — prefill traces
resolve to the streaming/Pallas paths, single-token decode to the O(L)
naive row; no attention implementation is imported here directly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.ctx import maybe_shard
from repro.models.mamba import mamba_apply, mamba_cache_init, mamba_init
from repro.models.mamba2 import mamba2_apply, mamba2_cache_init, mamba2_init
from repro.models.moe import moe_apply, moe_init

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# layout: periods / kinds
# --------------------------------------------------------------------------

def period_layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, int]:
    """→ (kinds within one period, n full periods, n tail layers)."""
    if cfg.family == "hybrid":
        per = max(cfg.hybrid_period, 1)
        kinds = ("mamba",) * per
    else:
        kinds = cfg.pattern
        per = len(kinds)
    nper, tail = divmod(cfg.num_layers, per)
    return kinds, nper, tail


# --------------------------------------------------------------------------
# per-layer init / apply / cache
# --------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str) -> Params:
    if kind == "mamba":
        init = mamba2_init if cfg.ssm_variant == "mamba2" else mamba_init
        return {"ln": L.norm_init(cfg, cfg.d_model), "mix": init(key, cfg)}
    ks = jax.random.split(key, 2)
    p = {"ln1": L.norm_init(cfg, cfg.d_model),
         "attn": L.attn_init(ks[0], cfg),
         "ln2": L.norm_init(cfg, cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg)
    if cfg.post_block_norm:
        p["ln1_post"] = L.norm_init(cfg, cfg.d_model)
        p["ln2_post"] = L.norm_init(cfg, cfg.d_model)
    return p


def _layer_apply(cfg: ModelConfig, kind: str, p: Params, x: jax.Array, *,
                 pos: jax.Array, cache: Optional[Params],
                 cache_index: Optional[jax.Array], causal: bool,
                 page_table: Optional[jax.Array] = None,
                 q_len: Optional[jax.Array] = None,
                 token_pages: Optional[jax.Array] = None,
                 cu_seqlens: Optional[jax.Array] = None,
                 kernel_config=None,
                 tp_axis: Optional[str] = None
                 ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        apply = mamba2_apply if cfg.ssm_variant == "mamba2" else mamba_apply
        h, new_cache = apply(cfg, p["mix"], L.norm_apply(cfg, p["ln"], x),
                             cache=cache)
        return x + h, new_cache, aux
    a, new_cache = L.attn_apply(cfg, p["attn"], L.norm_apply(cfg, p["ln1"], x),
                                kind=kind, pos=pos, causal=causal,
                                cache=cache, cache_index=cache_index,
                                page_table=page_table, q_len=q_len,
                                token_pages=token_pages,
                                cu_seqlens=cu_seqlens,
                                kernel_config=kernel_config,
                                tp_axis=tp_axis)
    if cfg.post_block_norm:
        a = L.norm_apply(cfg, p["ln1_post"], a)
    x = x + a
    h_in = L.norm_apply(cfg, p["ln2"], x)
    if cfg.family == "moe":
        h, aux = moe_apply(cfg, p["moe"], h_in)
    else:
        h = L.mlp_apply(cfg, p["mlp"], h_in)
    if cfg.post_block_norm:
        h = L.norm_apply(cfg, p["ln2_post"], h)
    return x + h, new_cache, aux


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int
                 ) -> Params:
    if kind == "mamba":
        init = mamba2_cache_init if cfg.ssm_variant == "mamba2" else mamba_cache_init
        return init(cfg, batch)
    return L.attn_cache_init(cfg, batch, max_len, dtype=L._dtype(cfg),
                             kind=kind)


# --------------------------------------------------------------------------
# trunk
# --------------------------------------------------------------------------

def trunk_init(key, cfg: ModelConfig) -> Params:
    kinds, nper, tail = period_layout(cfg)

    def period_init(k):
        ks = jax.random.split(k, len(kinds))
        return {str(i): _layer_init(ks[i], cfg, kind)
                for i, kind in enumerate(kinds)}

    p: Params = {}
    if nper:
        p["periods"] = jax.vmap(period_init)(
            jax.random.split(jax.random.fold_in(key, 0), nper))
    if tail:
        ks = jax.random.split(jax.random.fold_in(key, 1), tail)
        p["tail"] = [_layer_init(ks[i], cfg, kinds[i % len(kinds)])
                     for i in range(tail)]
    if cfg.family == "hybrid":
        p["shared_attn"] = L.block_init(jax.random.fold_in(key, 2), cfg)
    return p


def trunk_cache_init(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    kinds, nper, tail = period_layout(cfg)

    def period_cache():
        c = {str(i): _layer_cache(cfg, kind, batch, max_len)
             for i, kind in enumerate(kinds)}
        if cfg.family == "hybrid":
            c["shared"] = L.attn_cache_init(cfg, batch, max_len,
                                            dtype=L._dtype(cfg))
        return c

    c: Params = {}
    if nper:
        c["periods"] = jax.tree.map(
            lambda a: jnp.zeros((nper,) + a.shape, a.dtype), period_cache())
    if tail:
        c["tail"] = [_layer_cache(cfg, kinds[i % len(kinds)], batch, max_len)
                     for i in range(tail)]
    return c


def trunk_apply(cfg: ModelConfig, params: Params, x: jax.Array, *,
                pos: jax.Array, caches: Optional[Params] = None,
                cache_index: Optional[jax.Array] = None, causal: bool = True,
                page_table: Optional[jax.Array] = None,
                q_len: Optional[jax.Array] = None,
                token_pages: Optional[jax.Array] = None,
                cu_seqlens: Optional[jax.Array] = None,
                kernel_config=None,
                tp_axis: Optional[str] = None
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    kinds, nper, tail = period_layout(cfg)
    shared = params.get("shared_attn")

    def period_apply(x, pp, pc):
        # Sequence-parallel residual stream: the scan carry is what remat
        # saves per period — sharding it over (dp, sp) is what keeps grok-1
        # training in HBM (DESIGN.md §4).
        x = maybe_shard(x, ("dp", "sp", None))
        new_c: Params = {}
        aux = jnp.zeros((), jnp.float32)
        if shared is not None:
            x, sc = L.block_apply(cfg, shared, x, pos=pos, causal=causal,
                                  cache=None if pc is None else pc["shared"],
                                  cache_index=cache_index)
            if pc is not None:
                new_c["shared"] = sc
        for i, kind in enumerate(kinds):
            x, lc, a = _layer_apply(
                cfg, kind, pp[str(i)], x, pos=pos,
                cache=None if pc is None else pc[str(i)],
                cache_index=cache_index, causal=causal,
                page_table=page_table, q_len=q_len,
                token_pages=token_pages, cu_seqlens=cu_seqlens,
                kernel_config=kernel_config, tp_axis=tp_axis)
            if pc is not None:
                new_c[str(i)] = lc
            aux = aux + a
        return x, (new_c if pc is not None else None), aux

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Params = {}
    if nper:
        if caches is None:
            def body(carry, pp):
                x, aux = carry
                x, _, a = period_apply(x, pp, None)
                return (x, aux + a), None
            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["periods"])
        else:
            def body(carry, xs):
                x, aux = carry
                pp, pc = xs
                x, nc, a = period_apply(x, pp, pc)
                return (x, aux + a), nc
            (x, aux_total), nc = jax.lax.scan(
                body, (x, aux_total), (params["periods"], caches["periods"]))
            new_caches["periods"] = nc
    if tail:
        new_caches["tail"] = []
        for i in range(tail):
            x, lc, a = _layer_apply(
                cfg, kinds[i % len(kinds)], params["tail"][i], x, pos=pos,
                cache=None if caches is None else caches["tail"][i],
                cache_index=cache_index, causal=causal,
                page_table=page_table, q_len=q_len,
                token_pages=token_pages, cu_seqlens=cu_seqlens,
                kernel_config=kernel_config, tp_axis=tp_axis)
            aux_total = aux_total + a
            new_caches["tail"].append(lc)
    return x, (new_caches if caches is not None else None), aux_total


# --------------------------------------------------------------------------
# full model: embed → trunk → norm → logits
# --------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p = {"embed": L.embed_init(ks[0], cfg),
         "trunk": trunk_init(ks[1], cfg),
         "final_norm": L.norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                                    dtype=L._dtype(cfg))
    return p


def lm_apply(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
             prefix_embed: Optional[jax.Array] = None,
             caches: Optional[Params] = None,
             cache_index: Optional[jax.Array] = None,
             causal: bool = True,
             page_table: Optional[jax.Array] = None,
             q_len: Optional[jax.Array] = None,
             logits_rows: Optional[int] = None
             ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """tokens (B, L) [+ optional (B, Lp, D) prefix] → logits (B, L', V).

    ``prefix_embed`` (vlm patches / audio frames) is prepended to the token
    embeddings; returned logits cover the full L' = Lp + L sequence.
    ``cache_index`` may be a (B,) vector (paged decode / chunked prefill:
    lanes at different positions) — positions then broadcast to (B, L).
    ``q_len`` (paged path only): per-lane live rows of a right-aligned block
    (see ``layers.attn_apply``).  ``logits_rows=n`` unembeds only the last
    ``n`` positions — serving steps sample one row per lane, and the (B, L,
    V) logits tensor is the largest activation in the step.
    """
    offset = jnp.asarray(0 if cache_index is None else cache_index, jnp.int32)
    lp = 0 if prefix_embed is None else prefix_embed.shape[1]
    # offset () → positions (L,); offset (B,) → per-lane positions (B, L)
    pos_tok = (offset[..., None] + lp
               + jnp.arange(tokens.shape[1], dtype=jnp.int32))
    x = L.embed_apply(cfg, params["embed"], tokens, pos_tok)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    pos = offset[..., None] + jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_caches, aux = trunk_apply(cfg, params["trunk"], x, pos=pos,
                                     caches=caches, cache_index=cache_index,
                                     causal=causal, page_table=page_table,
                                     q_len=q_len)
    x = L.norm_apply(cfg, params["final_norm"], x)
    if logits_rows is not None:
        x = x[:, -logits_rows:]
    logits = L.unembed_apply(cfg, params["embed"], params.get("lm_head"), x)
    # Keep the vocab dim sharded through the loss (logits are the largest
    # activation: batch × seq × vocab).
    logits = maybe_shard(logits, ("dp", None, "tp"))
    return logits, new_caches, aux


# --------------------------------------------------------------------------
# steps: loss / prefill / decode
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """CE that keeps a vocab-sharded logits tensor sharded.

    ``take_along_axis`` on a sharded vocab dim would force an all-gather of
    the (B, L, V) logits (tens of GiB/device at 4k×256); the masked-sum
    below reduces over the sharded dim instead — GSPMD turns it into a
    partial reduce + psum, and the iota==label mask fuses into the
    reduction (never materialised).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                      logits.ndim - 1)
    gold = jnp.sum(jnp.where(v_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    prefix = batch.get("prefix_embed")
    logits, _, aux = lm_apply(cfg, params, batch["tokens"],
                              prefix_embed=prefix)
    lp = 0 if prefix is None else prefix.shape[1]
    tok_logits = logits[:, lp:]
    ce = cross_entropy(tok_logits[:, :-1], batch["tokens"][:, 1:],
                       batch.get("loss_mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def lm_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
               caches: Params, *, prefix_embed: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Params]:
    """Fill the caches; returns (last-position logits (B, V), caches)."""
    logits, caches, _ = lm_apply(cfg, params, tokens,
                                 prefix_embed=prefix_embed, caches=caches,
                                 cache_index=jnp.zeros((), jnp.int32))
    return logits[:, -1], caches


def lm_decode_step(cfg: ModelConfig, params: Params, token: jax.Array,
                   caches: Params, index: jax.Array
                   ) -> Tuple[jax.Array, Params]:
    """One token (B,) at absolute position ``index`` → (logits (B, V), caches)."""
    logits, caches, _ = lm_apply(cfg, params, token[:, None], caches=caches,
                                 cache_index=index)
    return logits[:, -1], caches


def lm_prefill_chunk_paged(cfg: ModelConfig, params: Params,
                           tokens: jax.Array, caches: Params,
                           page_table: jax.Array, kv_len: jax.Array,
                           q_len: jax.Array) -> Tuple[jax.Array, Params]:
    """One unified serving step: a right-aligned (B, C) block of tokens per
    lane — ``q_len[b]`` live tokens ending at row ``kv_len[b] - 1``, the
    rest left-padding.  Decode lanes are ``q_len == 1``, prefill lanes carry
    a chunk of ``q_len ≤ C`` prompt tokens, idle lanes ``q_len == 0``; all
    phases share this one traced function (C ∈ {1, chunk} — shapes are
    static, so a stream of arbitrary prompt lengths compiles O(1) step
    functions instead of one per length bucket).

    Every live row's KV is written in place at its (physical page, in-page
    offset) through ``page_table`` (B, P) and attention runs through the
    table with the causal intra-chunk mask (``kernels/paged_attention``);
    padding rows write to the pool's scratch page.  No contiguous
    (B, …, n·page_size, …) cache view is ever materialised — chunked prefill
    is the same in-place dataflow as decode, which is what deletes the old
    contiguous-prefill-then-scatter copy (``write_prefill``).

    Returns (last-row logits (B, V), caches).  The last row is the lane's
    newest live token, so the caller samples from it exactly when the step
    consumed the lane's final known token.
    """
    c = tokens.shape[1]
    offset = jnp.asarray(kv_len, jnp.int32) - c        # block-start row
    logits, caches, _ = lm_apply(cfg, params, tokens, caches=caches,
                                 cache_index=offset, page_table=page_table,
                                 q_len=jnp.asarray(q_len, jnp.int32),
                                 logits_rows=1)
    return logits[:, -1], caches


def lm_step_ragged(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   caches: Params, token_pages: jax.Array, pos: jax.Array,
                   last_idx: jax.Array,
                   cu_seqlens: Optional[jax.Array] = None,
                   kernel_config=None,
                   sampling: Optional[Dict[str, jax.Array]] = None,
                   tp_axis: Optional[str] = None
                   ) -> Tuple[jax.Array, Params]:
    """The token-level (ragged) serving step: one packed ``(T,)`` stream.

    Where :func:`lm_prefill_chunk_paged` runs a right-aligned ``(lanes, C)``
    block — every decode lane padded to the prefill chunk width — this step
    flattens the batch to ``T = Σ live tokens`` rows (bucketed to a few
    widths by the scheduler): a step with 3 decode lanes and one 64-token
    prefill chunk costs 67 token-rows of compute, not 4 × 64.  ``tokens``
    (T,) is the packed stream (lane segments abutting, dead rows padding
    the tail), ``pos`` (T,) each token's absolute position (rope + causal
    bound), ``token_pages`` (T, P) each token's page-table row.  Every
    token's KV row is written in place at its (physical page, offset) and
    attention runs through the per-token tables (``paged_varlen``) — no
    ``(lanes, C)``-padded intermediate exists anywhere in this graph (the
    ragged-equivalence suite walks the jaxpr to prove it).

    Logit extraction is segment-masked: only ``last_idx`` — stream indices
    into the packed ``(T,)`` rows (duplicated/zero for idle lanes) — is
    unembedded.  ``last_idx`` (lanes,) → logits (lanes, V): each lane's
    final token this step; the caller samples lane ``i`` exactly when the
    step consumed that lane's last known token.  Speculative verify passes
    ``last_idx`` (lanes, 1 + k) → logits (lanes, 1 + k, V): the lane's
    decode row plus its k drafted rows, so one forward pass yields the
    argmax at every drafted position (the gather is still O(lanes · k)
    rows, never the (T, V) tensor, and there is no per-draft loop — the
    drafted rows ride the same packed stream).

    ``cu_seqlens`` (S+1,) lane boundaries (dead padding rows covered by a
    trailing pseudo-segment so ``cu[-1] == T``) switch the attention layers
    to the q-block-tiled varlen dataflow; ``kernel_config`` (static) pins
    the autotuned block shapes.

    ``sampling`` — per-lane arrays ``{temperature, top_k, top_p, seed,
    counter}``, each ``(lanes,)`` — moves token selection *into this
    graph*: instead of (logits, caches) the step returns (tokens, caches),
    where tokens are (lanes,) int32 (or (lanes, 1+k) for speculative
    verify, rows ≥ 1 greedy).  The draw is one vectorized pass over the
    last-idx logits through the same LUT-exp/softmax machinery the
    attention layers use (``serving/sampling.sample_in_step``) — no host
    round-trip between logits and token, and the (lanes, V) tensor never
    leaves the device.  All five arrays are traced data, so sampling
    params can never trigger a retrace.

    ``tp_axis`` — mesh axis name when this step runs inside ``shard_map``
    over a KV-head-sharded page pool (``EngineCore(mesh=N)``): every
    attention layer then attends its local head band against its local
    pool shard and all-gathers the head axis (see ``layers.attn_apply``);
    embed/norms/MLP/unembed/sampling run replicated and unchanged.
    """
    p_tok = jnp.asarray(pos, jnp.int32)
    x = L.embed_apply(cfg, params["embed"], tokens[None], p_tok[None])
    x, caches, _ = trunk_apply(cfg, params["trunk"], x, pos=p_tok[None],
                               caches=caches, cache_index=None, causal=True,
                               token_pages=token_pages, cu_seqlens=cu_seqlens,
                               kernel_config=kernel_config, tp_axis=tp_axis)
    x = L.norm_apply(cfg, params["final_norm"], x)
    # (lanes,) gather BEFORE unembedding: the (T, V) logits tensor would be
    # the largest activation of the step; only lanes' last rows are needed.
    idx = jnp.asarray(last_idx, jnp.int32)
    x = jnp.take(x[0], idx, axis=0)       # (lanes, D) or (lanes, 1+k, D)
    logits = L.unembed_apply(cfg, params["embed"], params.get("lm_head"), x)
    spec = ("dp", "tp") if idx.ndim == 1 else ("dp", None, "tp")
    logits = maybe_shard(logits, spec)
    if sampling is None:
        return logits, caches
    # In-step sampling: logits → tokens without leaving the graph.
    # Deferred import — repro.serving imports repro.models at module load;
    # resolving the sampler at trace time keeps the packages acyclic.
    from repro.serving.sampling import sample_in_step
    return sample_in_step(logits, **sampling), caches
