"""Model zoo: every assigned architecture family, pure functional JAX.

``build_model(cfg)`` returns the uniform init/loss/prefill/decode API used by
the launcher, trainer, serving engine, and dry-run (see ``models.api``).
"""
from repro.models.api import (Model, batch_specs, build_model, cache_specs,
                              init_params, input_specs)

__all__ = ["Model", "build_model", "init_params", "input_specs",
           "batch_specs", "cache_specs"]
