"""Encoder–decoder backbone (seamless-m4t-large-v2).

The speech frontend (w2v-BERT conformer) is a STUB per the assignment:
inputs are precomputed frame embeddings (B, L_src, d_model).  Encoder layers
are bidirectional attention blocks; decoder layers add cross-attention whose
K/V are computed **once** from the encoder output and cached (the decode path
never re-projects the encoder states).  Self-attention uses the HASTILY
streaming path like every other family.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention_api import attention, backend_for_config
from repro.models import layers as L
from repro.models.lm import cross_entropy

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# cross-attention with precomputed K/V
# --------------------------------------------------------------------------

def _cross_kv(cfg: ModelConfig, p: Params, enc_out: jax.Array
              ) -> Dict[str, jax.Array]:
    k = L._heads(L.dense_apply(p["wk"], enc_out), cfg.num_kv_heads)
    v = L._heads(L.dense_apply(p["wv"], enc_out), cfg.num_kv_heads)
    return {"k": k, "v": v}


def _cross_attn(cfg: ModelConfig, p: Params, x: jax.Array,
                kv: Dict[str, jax.Array]) -> jax.Array:
    b, l, _ = x.shape
    q = L._heads(L.dense_apply(p["wq"], x), cfg.num_heads)
    scale = cfg.attn_scale if cfg.attn_scale else cfg.d_head ** -0.5
    out = attention(q, kv["k"], kv["v"],
                    backend=backend_for_config(cfg.attn_backend,
                                               cfg.attn_impl),
                    scale=scale, causal=False, block_k=cfg.block_k,
                    exp_mode=cfg.exp_mode, fallback=True)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, cfg.num_heads * cfg.d_head)
    return L.dense_apply(p["wo"], out)


def _dec_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg, cfg.d_model),
            "self_attn": L.attn_init(ks[0], cfg),
            "lnx": L.norm_init(cfg, cfg.d_model),
            "cross_attn": L.attn_init(ks[1], cfg),
            "ln2": L.norm_init(cfg, cfg.d_model),
            "mlp": L.mlp_init(ks[2], cfg)}


def _dec_block_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
                     pos: jax.Array, cross_kv: Dict[str, jax.Array],
                     cache: Optional[Params], cache_index) -> Tuple:
    a, new_cache = L.attn_apply(cfg, p["self_attn"],
                                L.norm_apply(cfg, p["ln1"], x), pos=pos,
                                causal=True, cache=cache,
                                cache_index=cache_index)
    x = x + a
    x = x + _cross_attn(cfg, p["cross_attn"],
                        L.norm_apply(cfg, p["lnx"], x), cross_kv)
    x = x + L.mlp_apply(cfg, p["mlp"], L.norm_apply(cfg, p["ln2"], x))
    return x, new_cache


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------

def encdec_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "embed": L.embed_init(ks[2], cfg),
        "encoder": jax.vmap(lambda k: L.block_init(k, cfg))(enc_keys),
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "dec_norm": L.norm_init(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames (B, L_src, D) stub embeddings → encoder output (B, L_src, D)."""
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    x = frames.astype(L._dtype(cfg))

    def body(x, pp):
        x, _ = L.block_apply(cfg, pp, x, pos=pos, causal=False)
        return x, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.norm_apply(cfg, params["enc_norm"], x)


def decode_trunk(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 cross_kvs: Params, *, caches: Optional[Params] = None,
                 cache_index=None) -> Tuple[jax.Array, Optional[Params]]:
    offset = jnp.asarray(0 if cache_index is None else cache_index, jnp.int32)
    pos = offset + jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x = L.embed_apply(cfg, params["embed"], tokens, pos)

    if caches is None:
        def body(x, xs):
            pp, ckv = xs
            x, _ = _dec_block_apply(cfg, pp, x, pos=pos, cross_kv=ckv,
                                    cache=None, cache_index=None)
            return x, None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, (params["decoder"], cross_kvs))
        new_caches = None
    else:
        def body(x, xs):
            pp, ckv, cc = xs
            x, nc = _dec_block_apply(cfg, pp, x, pos=pos, cross_kv=ckv,
                                     cache=cc, cache_index=cache_index)
            return x, nc
        x, new_caches = jax.lax.scan(
            body, x, (params["decoder"], cross_kvs, caches))
    x = L.norm_apply(cfg, params["dec_norm"], x)
    logits = L.unembed_apply(cfg, params["embed"], None, x)
    return logits, new_caches


def cross_kvs_init(cfg: ModelConfig, params: Params, enc_out: jax.Array
                   ) -> Params:
    """Project encoder output to stacked per-decoder-layer cross K/V."""
    return jax.vmap(lambda pp: _cross_kv(cfg, pp["cross_attn"], enc_out)
                    )(params["decoder"])


def encdec_loss(cfg: ModelConfig, params: Params,
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    enc_out = encode(cfg, params, batch["frames"])
    ckv = cross_kvs_init(cfg, params, enc_out)
    logits, _ = decode_trunk(cfg, params, batch["tokens"], ckv)
    ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:],
                       batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def encdec_cache_init(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    cache = L.attn_cache_init(cfg, batch, max_len, dtype=L._dtype(cfg))
    return jax.tree.map(lambda a: jnp.zeros((cfg.dec_layers,) + a.shape,
                                            a.dtype), cache)


def encdec_prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
                   tokens: jax.Array, caches: Params
                   ) -> Tuple[jax.Array, Params, Params]:
    """Encode + prefill the decoder.  Returns (last logits, self caches, cross K/V)."""
    enc_out = encode(cfg, params, frames)
    ckv = cross_kvs_init(cfg, params, enc_out)
    logits, caches = decode_trunk(cfg, params, tokens, ckv, caches=caches,
                                  cache_index=jnp.zeros((), jnp.int32))
    return logits[:, -1], caches, ckv


def encdec_decode_step(cfg: ModelConfig, params: Params, token: jax.Array,
                       caches: Params, cross_kvs: Params, index: jax.Array
                       ) -> Tuple[jax.Array, Params]:
    logits, caches = decode_trunk(cfg, params, token[:, None], cross_kvs,
                                  caches=caches, cache_index=index)
    return logits[:, -1], caches
