"""Shared transformer building blocks (pure functional JAX).

Every layer is a pair of functions: ``*_init(key, cfg, ...) -> params`` and
``*_apply(cfg, params, x, ...) -> y``.  Params are plain nested dicts of
jnp arrays so they flow through jit / shard_map / checkpointing unchanged and
sharding rules can be assigned by leaf path (``parallel/sharding.py``).

Attention dispatches through the backend registry (``core/attention_api``):
``cfg.attn_backend`` names a registered implementation ("jnp", "pallas",
"ring", "naive") or "auto" to resolve per-call from device platform and call
shape.  The legacy ``cfg.attn_impl`` field keeps working via
``backend_for_config``.  The INT8-quantised KV path keeps its dedicated
entry point (different operand signature).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention_api import attention, backend_for_config
from repro.core.streaming_attention import (quantize_kv_rows,
                                            streaming_attention_quantized)

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# dense / norms / embeddings
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...k,kn->...n", x, p["w"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def norm_init(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), _dtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg))
    return p


def norm_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm (gemma-style: scale offset by 1)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array) -> jax.Array:
    """Per-head RMS norm on q/k (gemma3 qk_norm).  x: (B, H, L, Dh)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def embed_init(key, cfg: ModelConfig) -> Params:
    p = {"tokens": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                      jnp.float32) * 0.02).astype(_dtype(cfg))}
    if cfg.pos_embedding == "learned":
        p["positions"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.max_position, cfg.d_model),
            jnp.float32) * 0.02).astype(_dtype(cfg))
    return p


def embed_apply(cfg: ModelConfig, p: Params, tokens: jax.Array,
                pos: jax.Array) -> jax.Array:
    x = jnp.take(p["tokens"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_embedding == "learned":
        x = x + jnp.take(p["positions"], pos, axis=0)
    return x


def unembed_apply(cfg: ModelConfig, embed_p: Params, head_p: Optional[Params],
                  x: jax.Array) -> jax.Array:
    """Final logits; tied → reuse the token table.  Applies gemma final softcap."""
    if cfg.tie_embeddings or head_p is None:
        logits = jnp.einsum("...d,vd->...v", x, embed_p["tokens"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,dv->...v", x, head_p["w"],
                            preferred_element_type=jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_apply(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, H, L, D); pos: (L,) absolute positions, or
    (B, L) when lanes sit at different positions (batched paged decode)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = pos.astype(jnp.float32)[..., :, None] * freqs   # (…, L, D/2)
    if angles.ndim == 3:
        angles = angles[:, None]                             # (B, 1, L, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# attention block
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, dh = cfg.d_model, cfg.d_head
    dt = _dtype(cfg)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * dh, bias=cfg.attn_bias, dtype=dt),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * dh, bias=cfg.attn_bias, dtype=dt),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * dh, bias=cfg.attn_bias, dtype=dt),
        "wo": dense_init(ks[3], cfg.num_heads * dh, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _heads(x: jax.Array, n: int) -> jax.Array:
    b, l, hd = x.shape
    return x.reshape(b, l, n, hd // n).transpose(0, 2, 1, 3)  # (B,H,L,Dh)


def attn_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
               kind: str = "global",
               pos: jax.Array,
               causal: bool = True,
               cache: Optional[Params] = None,
               cache_index: Optional[jax.Array] = None,
               page_table: Optional[jax.Array] = None,
               q_len: Optional[jax.Array] = None,
               token_pages: Optional[jax.Array] = None,
               cu_seqlens: Optional[jax.Array] = None,
               kernel_config=None,
               tp_axis: Optional[str] = None,
               xkv: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, Optional[Params]]:
    """One attention layer.

    ``pos``: (L,) absolute positions of the query rows ((B, L) when lanes
    decode at different positions — the paged path).
    ``cache``: {"k","v"} of shape (B, Hkv, Lmax, Dh) for decode; new K/V rows
    are written at ``cache_index`` and attention runs against the whole cache
    with ``kv_len = cache_index + L``.
    ``page_table``: (B, P) physical-page table — ``cache`` leaves are then
    *page pools* (num_pages, Hkv, page_size, Dh) shared by all lanes and
    ``cache_index`` is the (B,) absolute row of the block's first query (so
    ``kv_len = cache_index + L``).  Each live row's K/V is written straight
    into its physical page and attention runs in place through the table (no
    gathered contiguous cache view): L == 1 is decode, L > 1 a chunked
    prefill block.
    ``q_len``: (B,) live rows per lane in a right-aligned paged block (rows
    before ``L - q_len`` are padding: their writes land on the pool's
    scratch page and their outputs are garbage the caller never reads).
    ``None`` means every row is live (the decode path).
    ``token_pages``: (T, P) per-token page-table rows — switches the paged
    path to the *ragged* packed-stream convention: x is one ``(1, T,
    d_model)`` stream of live tokens from many lanes (no per-lane padding),
    ``pos`` carries each token's absolute position (1, T), each token's KV
    row is written at its own (page, offset) and attention runs through the
    per-token table with per-token causal bounds (``paged_varlen``).  Dead
    rows (stream padding to the bucket width) carry an all-scratch table
    row; their writes land on the scratch page, their outputs are garbage
    the caller never reads.
    ``cu_seqlens``: (S+1,) ragged-stream lane boundaries — enables the
    q-block-tiled varlen dataflow (each KV page read once per q-block);
    ``kernel_config``: the autotuned ``KernelConfig`` block shapes (static;
    ``None`` consults the autotuner's active config).
    ``tp_axis``: mesh axis name when this apply runs *inside shard_map*
    over KV-head-sharded page pools (the tensor-parallel ragged step).
    The residual stream, params and projections stay replicated; this
    layer slices its own contiguous head band (rope/qk_norm are per-head,
    so slicing after them is bit-identical to projecting the band alone),
    writes the band's KV rows into the local pool shard, attends over
    local heads only, and rebuilds the full head axis with one tiled
    all-gather before ``wo``.  Ragged (``token_pages``) path only.
    ``xkv``: cross-attention source (encoder output); disables cache/rope-k.
    """
    b, l, _ = x.shape
    q = _heads(dense_apply(p["wq"], x), cfg.num_heads)
    kv_src = x if xkv is None else xkv
    k = _heads(dense_apply(p["wk"], kv_src), cfg.num_kv_heads)
    v = _heads(dense_apply(p["wv"], kv_src), cfg.num_kv_heads)

    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)

    window = cfg.window if kind == "local" else None
    theta = cfg.rope_theta
    if kind == "local" and cfg.local_rope_theta is not None:
        theta = cfg.local_rope_theta

    if cfg.pos_embedding == "rope" and xkv is None:
        q = rope_apply(q, pos, theta)
        k = rope_apply(k, pos, theta)
    elif cfg.pos_embedding == "rope":
        q = rope_apply(q, pos, theta)
        k = rope_apply(k, jnp.arange(k.shape[2], dtype=jnp.int32), theta)

    scale_default = cfg.attn_scale if cfg.attn_scale else cfg.d_head ** -0.5
    if cache is not None and (token_pages is not None
                              or page_table is not None):
        # Paged attention, two packings over one write path.  Cache leaves
        # are page pools; every live row's K/V is written in place at its
        # (physical page, in-page offset) and attention reads through the
        # tables — no gathered (B, …, P·ps, …) view exists.
        #
        # - padded block (`page_table` (B, P)): right-aligned rows at
        #   absolute positions cache_index + i; L == 1 is decode, L > 1 a
        #   chunked-prefill block; rows before L - q_len are padding.
        # - ragged stream (`token_pages` (T, P)): x is ONE (1, T, d) packed
        #   stream of live tokens from many lanes, each with its own
        #   position (causal bound) and page-table row.  Intra-chunk
        #   causality holds because a chunk's rows are written before the
        #   attend; cross-lane isolation because a token's table row names
        #   only its own lane's pages.  Dead bucket-padding rows carry an
        #   all-scratch table row.
        assert xkv is None, "paged attention has no cross-attention path"
        # Tensor-parallel ragged step: the local pool shard's head count
        # tells us the shard factor (static — compat.axis_size is traced on
        # 0.4.x); the device index only feeds a dynamic_slice start.
        shards = 1
        if tp_axis is not None:
            assert token_pages is not None, \
                "tp_axis is only supported on the ragged (token_pages) path"
            hkv_local = cache["k"].shape[1]
            shards = cfg.num_kv_heads // hkv_local
        if shards > 1:
            hq_local = cfg.num_heads // shards
            band = jax.lax.axis_index(tp_axis)
            q = jax.lax.dynamic_slice_in_dim(q, band * hq_local, hq_local, 1)
            k = jax.lax.dynamic_slice_in_dim(k, band * hkv_local,
                                             hkv_local, 1)
            v = jax.lax.dynamic_slice_in_dim(v, band * hkv_local,
                                             hkv_local, 1)
        ps = cache["k"].shape[2]
        scratch = cache["k"].shape[0] - 1               # pool's sink page
        if token_pages is not None:
            p_tok = jnp.asarray(pos, jnp.int32).reshape(-1)     # (T,)
            slot = jnp.clip(p_tok // ps, 0, token_pages.shape[1] - 1)
            pids = jnp.take_along_axis(token_pages, slot[:, None], axis=1).T
            off = (p_tok % ps)[None]                    # (1, T) like pids
        else:
            idx = jnp.asarray(cache_index, jnp.int32)   # (B,) block start
            kv_len = idx + l
            rows = idx[:, None] + jnp.arange(l, dtype=jnp.int32)[None]
            if q_len is None:
                live = jnp.ones(rows.shape, bool)       # decode: all rows
            else:
                live = (jnp.arange(l, dtype=jnp.int32)[None]
                        >= l - jnp.asarray(q_len, jnp.int32)[:, None])
            # Padding rows (and their possibly-negative positions) must
            # never touch a live page: clamp the table lookup, then route
            # them to the scratch page (masked by kv_len on every read).
            slot = jnp.clip(rows // ps, 0, page_table.shape[1] - 1)
            pids = jnp.where(live,
                             jnp.take_along_axis(page_table, slot, axis=1),
                             scratch)                   # (B, L)
            off = rows % ps

        def put(pool, val):
            # val (B, Hkv, L, …) → rows-major (B, L, Hkv, …); the advanced
            # (B, L) page/offset indices scatter one row at a time — the
            # transient is O(B·L), never the (B, P·ps, …) gathered view.
            # (Ragged: B == 1, L == T, indices shaped (1, T).)
            return pool.at[pids, :, off].set(
                jnp.moveaxis(val, 2, 1).astype(pool.dtype))

        attn_kw = dict(scale=scale_default, cap=cfg.attn_softcap,
                       window=window, exp_mode=cfg.exp_mode)
        if "ks" in cache:                    # INT8 pool: values + row scales
            kq_new, ks_new = quantize_kv_rows(k)
            vq_new, vs_new = quantize_kv_rows(v)
            new_cache = {
                "k": put(cache["k"], kq_new), "v": put(cache["v"], vq_new),
                "ks": put(cache["ks"], ks_new), "vs": put(cache["vs"], vs_new),
            }
            from repro.kernels.paged_attention import (
                paged_attention, paged_attention_varlen)
            attn_kw.update(k_scale=new_cache["ks"], v_scale=new_cache["vs"])
            if token_pages is not None:
                from repro.kernels.autotune import active_config
                kc = (kernel_config if kernel_config is not None
                      else active_config())
                out = paged_attention_varlen(
                    jnp.moveaxis(q[0], 1, 0), new_cache["k"], new_cache["v"],
                    token_pages, p_tok, cu_seqlens=cu_seqlens,
                    block_q=kc.block_q, block_pages=kc.block_pages,
                    dequant=kc.dequant, **attn_kw)      # (T, Hq', Dh)
                out = jnp.moveaxis(out, 0, 1)[None]     # (1, Hq', T, Dh)
                if shards > 1:
                    out = jax.lax.all_gather(out, tp_axis, axis=1,
                                             tiled=True)
            else:
                out = paged_attention(q, new_cache["k"], new_cache["v"],
                                      page_table, kv_len, **attn_kw)
        else:
            new_cache = {"k": put(cache["k"], k), "v": put(cache["v"], v)}
            conv = (dict(q_pos=p_tok, page_table=token_pages,
                         cu_seqlens=cu_seqlens, kernel_config=kernel_config)
                    if token_pages is not None
                    else dict(kv_len=kv_len, page_table=page_table))
            if shards > 1:
                conv["axis_name"] = tp_axis     # varlen backend all-gathers
            out = attention(q, new_cache["k"], new_cache["v"],
                            backend=backend_for_config(cfg.attn_backend,
                                                       cfg.attn_impl),
                            causal=causal, block_k=cfg.block_k,
                            fallback=True, **attn_kw, **conv)
        out = out.transpose(0, 2, 1, 3).reshape(b, l,
                                                cfg.num_heads * cfg.d_head)
        return dense_apply(p["wo"], out), new_cache

    new_cache = None
    q_offset = 0
    kv_len = None
    kv_pos = None
    if cache is not None and "pos" in cache:
        # Ring-buffer sliding-window cache (local layers at long context):
        # capacity Lc == window; slot = position mod Lc; cache["pos"] tracks
        # each slot's absolute position (-1 = never written).  Prefill (l > 1,
        # assumes an empty cache) attends within the chunk and then writes the
        # last Lc rows; decode (l == 1) writes then attends against the ring.
        idx = jnp.asarray(cache_index, jnp.int32)
        lc = cache["k"].shape[2]
        if l == 1:
            # decode: one ring slot — dynamic_update_slice is shard-local,
            # whereas a traced-index scatter costs a collective-permute of
            # the whole cache under GSPMD (§Perf pair 3).
            slot = idx % lc
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
            pc = jax.lax.dynamic_update_slice(
                cache["pos"], jnp.broadcast_to(idx, (b, 1)).astype(jnp.int32),
                (0, slot))
        else:
            keep = min(l, lc)
            pos_keep = idx + l - keep + jnp.arange(keep, dtype=jnp.int32)
            slots = pos_keep % lc
            kc = cache["k"].at[:, :, slots].set(
                k[:, :, l - keep:].astype(cache["k"].dtype))
            vc = cache["v"].at[:, :, slots].set(
                v[:, :, l - keep:].astype(cache["v"].dtype))
            pc = cache["pos"].at[:, slots].set(pos_keep[None, :])
        new_cache = {"k": kc, "v": vc, "pos": pc}
        if l == 1:
            k, v = kc, vc
            kv_pos = pc
        q_offset = idx
    elif cache is not None and "ks" in cache:
        # INT8-quantised KV cache (cfg.kv_quant): rows are quantised on
        # write, the resident cache stays int8 + per-row f32 scales, and
        # attention dequantises block-by-block inside its scan.
        idx = jnp.asarray(cache_index, jnp.int32)
        kq_new, ks_new = quantize_kv_rows(k)
        vq_new, vs_new = quantize_kv_rows(v)
        kc = jax.lax.dynamic_update_slice(cache["k"], kq_new, (0, 0, idx, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], vq_new, (0, 0, idx, 0))
        ks = jax.lax.dynamic_update_slice(cache["ks"], ks_new, (0, 0, idx))
        vs = jax.lax.dynamic_update_slice(cache["vs"], vs_new, (0, 0, idx))
        new_cache = {"k": kc, "v": vc, "ks": ks, "vs": vs}
        scale = cfg.attn_scale if cfg.attn_scale else cfg.d_head ** -0.5
        out = streaming_attention_quantized(
            q, kc, vc, ks, vs, scale=scale, causal=causal and xkv is None,
            window=window, cap=cfg.attn_softcap, block_k=cfg.block_k,
            exp_mode=cfg.exp_mode, q_offset=idx, kv_len=idx + l)
        out = out.transpose(0, 2, 1, 3).reshape(b, l,
                                                cfg.num_heads * cfg.d_head)
        return dense_apply(p["wo"], out), new_cache
    elif cache is not None:
        idx = jnp.asarray(cache_index, jnp.int32)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, idx, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, idx, 0))
        new_cache = {"k": kc, "v": vc}
        k, v = kc, vc
        q_offset = idx
        kv_len = idx + l

    scale = cfg.attn_scale if cfg.attn_scale else cfg.d_head ** -0.5
    # Registry dispatch: fallback=True degrades an explicit backend that
    # cannot serve this call (e.g. "pallas" on the traced-length cached
    # decode path) to auto resolution instead of raising mid-trace.
    out = attention(q, k, v,
                    backend=backend_for_config(cfg.attn_backend,
                                               cfg.attn_impl),
                    scale=scale, causal=causal and xkv is None, window=window,
                    cap=cfg.attn_softcap, block_k=cfg.block_k,
                    exp_mode=cfg.exp_mode, q_offset=q_offset, kv_len=kv_len,
                    kv_pos=kv_pos, fallback=True)

    out = out.transpose(0, 2, 1, 3).reshape(b, l, cfg.num_heads * cfg.d_head)
    return dense_apply(p["wo"], out), new_cache


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16, kind: str = "global") -> Params:
    """KV cache.  Local layers at long context get a ring buffer of capacity
    ``window`` (O(window) memory instead of O(max_len)) with per-slot absolute
    positions — the cache-side statement of HASTILY's O(l)→O(1) streaming."""
    if kind == "local" and cfg.window is not None and cfg.window < max_len:
        lc = cfg.window
        shape = (batch, cfg.num_kv_heads, lc, cfg.d_head)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.full((batch, lc), -1, jnp.int32)}
    shape = (batch, cfg.num_kv_heads, max_len, cfg.d_head)
    if cfg.kv_quant:
        # INT8 cache: 2× (vs bf16) / 4× (vs f32) smaller resident state.
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:3], jnp.float32),
                "vs": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {"up": dense_init(ks[0], d, f, bias=cfg.attn_bias and not cfg.mlp_gated, dtype=dt),
         "down": dense_init(ks[1], f, d, bias=cfg.attn_bias and not cfg.mlp_gated, dtype=dt)}
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[2], d, f, dtype=dt)
    return p


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    act = _ACTS[cfg.act]
    h = dense_apply(p["up"], x)
    if cfg.mlp_gated:
        h = act(dense_apply(p["gate"], x)) * h
    else:
        h = act(h)
    return dense_apply(p["down"], h)


# --------------------------------------------------------------------------
# transformer block (pre-norm or BERT post-norm; optional gemma post norms)
# --------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p = {"ln1": norm_init(cfg, cfg.d_model),
         "attn": attn_init(ks[0], cfg),
         "ln2": norm_init(cfg, cfg.d_model),
         "mlp": mlp_init(ks[1], cfg)}
    if cfg.post_block_norm:
        p["ln1_post"] = norm_init(cfg, cfg.d_model)
        p["ln2_post"] = norm_init(cfg, cfg.d_model)
    return p


def block_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
                kind: str = "global", pos: jax.Array, causal: bool = True,
                cache: Optional[Params] = None,
                cache_index: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Params]]:
    if cfg.postnorm:  # BERT: sublayer → add → LN
        a, new_cache = attn_apply(cfg, p["attn"], x, kind=kind, pos=pos,
                                  causal=causal, cache=cache,
                                  cache_index=cache_index)
        x = norm_apply(cfg, p["ln1"], x + a)
        x = norm_apply(cfg, p["ln2"], x + mlp_apply(cfg, p["mlp"], x))
        return x, new_cache
    a, new_cache = attn_apply(cfg, p["attn"], norm_apply(cfg, p["ln1"], x),
                              kind=kind, pos=pos, causal=causal, cache=cache,
                              cache_index=cache_index)
    if cfg.post_block_norm:
        a = norm_apply(cfg, p["ln1_post"], a)
    x = x + a
    h = mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["ln2"], x))
    if cfg.post_block_norm:
        h = norm_apply(cfg, p["ln2_post"], h)
    return x + h, new_cache
