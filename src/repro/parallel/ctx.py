"""Activation-sharding context: mesh-aware models without mesh plumbing.

Model code is pure and mesh-agnostic; at scale, though, two activations MUST
carry explicit sharding constraints or remat/propagation blows per-chip
memory (napkin math in DESIGN.md §4):

- the residual-stream scan carry (saved once per period by remat — 64 ×
  805 MB/chip on grok-1 without sequence-parallel sharding, 64 × 50 MB with);
- the final logits (batch × seq × vocab — vocab must stay sharded through
  the cross-entropy).

``activation_sharding(mesh)`` installs a process-local mesh; ``maybe_shard``
is a no-op without it, so CPU tests and single-device runs are untouched.
Specs are logical (see ``sharding.logical_axes``) and are ``fit_spec``-ed, so
non-divisible dims degrade to replicated instead of failing to compile.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import fit_spec

_STATE = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh]):
    prev = active_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def maybe_shard(x: jax.Array, logical: Sequence) -> jax.Array:
    """with_sharding_constraint(x, fit(logical)) if a mesh is active.

    Internal constraints may shard unevenly (e.g. a 151655-entry vocab over
    16 chips) — GSPMD pads; only jit *argument* shardings need divisibility.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = fit_spec(tuple(logical), x.shape, mesh, allow_uneven=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
