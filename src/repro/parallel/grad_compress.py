"""Gradient compression with error feedback for the DP all-reduce.

At 1000+ nodes the gradient all-reduce dominates the step at small per-chip
batch.  Casting gradients to bf16 *before* the reduction halves the bytes on
the wire; the quantisation error is carried in a per-leaf residual buffer
and re-injected next step (error feedback), so the *accumulated* update is
unbiased — SGD/Adam convergence is preserved (Karimireddy et al., 2019).

Two entry points:
- ``compress_with_feedback`` / state — the transform the trainer applies to
  per-shard gradients before they cross the mesh (in pjit the reduction is
  implicit; casting the gradient leaves to bf16 makes XLA emit bf16
  all-reduces, which is exactly the wire saving);
- ``compressed_psum`` — the explicit shard_map form, for code that owns its
  collectives (ring attention, the multicore softmax path).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def feedback_init(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: Params, residual: Params
                           ) -> Tuple[Params, Params]:
    """→ (bf16 gradients to reduce, new residual).

    residual' = (g + residual) − bf16(g + residual); the low-order bits lost
    to the cast are replayed into the next step instead of discarded.
    """
    def comp(g, r):
        corrected = g.astype(jnp.float32) + r
        sent = corrected.astype(jnp.bfloat16)
        return sent, corrected - sent.astype(jnp.float32)

    sent = jax.tree.map(lambda g, r: comp(g, r)[0], grads, residual)
    new_r = jax.tree.map(lambda g, r: comp(g, r)[1], grads, residual)
    return sent, new_r


def decompress(grads: Params, like: Params) -> Params:
    return jax.tree.map(lambda g, p: g.astype(jnp.float32), grads, like)


def compressed_psum(tree: Params, axis_name: str) -> Params:
    """bf16-on-the-wire psum for use inside shard_map."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name
                               ).astype(jnp.float32), tree)
