"""Sharding rules: map parameter/batch/cache pytrees to PartitionSpecs.

Logical axes → physical mesh axes:

  ``dp``/``fsdp`` → ("pod", "data")   batch parallel / ZeRO-3 param sharding
  ``tp``          → ("model",)        tensor parallel (heads / ffn / vocab)
  ``sp``          → ("model",)        sequence parallel (activations)

Rules are keyed by leaf *path suffix* (the model params are plain nested
dicts, so the path is stable and readable, e.g.
``trunk/periods/0/attn/wq/w``).  Every spec is passed through ``fit_spec``
which drops any mesh axis that does not divide the corresponding dim — so
one rule set serves every architecture and mesh (e.g. grok's 8 KV heads on a
16-way model axis fall back to replicated heads, and batch-1 long-context
decode falls back to model-only sharding) and compilation can never fail on
divisibility.

Design notes (HASTILY → TPU mapping, DESIGN.md §4):
- 2D weight sharding (fsdp × tp) is what lets grok-1-314b's optimizer state
  fit: 314B params spread over all 256/512 chips, not just the model axis.
- MoE expert FFNs shard d_model over fsdp and d_ff over tp (expert count is
  rarely divisible by an axis; the einsum dispatch keeps experts local).
- in_proj matrices whose *output* dim is a concatenation of segments
  (mamba/mamba2 fused projections) keep that dim replicated — slicing a
  sharded dim would force a resharding collective per layer.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Leaf = Any

# --------------------------------------------------------------------------
# logical → physical
# --------------------------------------------------------------------------


def logical_axes(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    names = mesh.axis_names
    dp = tuple(n for n in ("pod", "data") if n in names)
    tp = tuple(n for n in ("model",) if n in names)
    return {"dp": dp, "fsdp": dp, "tp": tp, "sp": tp}


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def fit_spec(spec: Sequence, shape: Tuple[int, ...], mesh: Mesh,
             allow_uneven: bool = False) -> P:
    """Drop axes that don't divide their dim; resolve logical names.

    ``allow_uneven=True`` keeps an axis whenever dim ≥ axis size (GSPMD pads
    internally) — legal only for *internal* sharding constraints
    (with_sharding_constraint); jit argument shardings require exact
    divisibility.
    """
    log = logical_axes(mesh)
    out = []
    used: set = set()
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        phys = log.get(ax, (ax,)) if isinstance(ax, str) else tuple(ax)
        phys = tuple(a for a in phys if a in mesh.axis_names and a not in used)
        # greedily keep the longest admissible prefix
        keep: Tuple[str, ...] = ()
        size = 1
        for a in phys:
            nxt = size * mesh.shape[a]
            if dim % nxt == 0 or (allow_uneven and dim >= nxt):
                keep += (a,)
                size = nxt
            else:
                break
        used.update(keep)
        out.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (path-suffix regex, logical spec for the *trailing* dims). Checked in order;
# leading stacked dims (scan-over-periods, expert stacks handled explicitly)
# are padded with None.
_PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / head
    (r"embed/tokens$", ("tp", "fsdp")),
    (r"embed/positions$", (None, "tp")),
    (r"lm_head/w$", ("fsdp", "tp")),
    # attention
    (r"attn/wq/w$", ("fsdp", "tp")),
    (r"attn/wk/w$", ("fsdp", "tp")),
    (r"attn/wv/w$", ("fsdp", "tp")),
    (r"attn/wo/w$", ("tp", "fsdp")),
    (r"attn/w[qkv]/b$", ("tp",)),
    (r"attn/wo/b$", (None,)),
    (r"attn/[qk]_norm$", (None,)),
    # dense mlp
    (r"mlp/(up|gate)/w$", ("fsdp", "tp")),
    (r"mlp/down/w$", ("tp", "fsdp")),
    (r"mlp/(up|gate|down)/b$", (None,)),
    # moe (E, D, F) stacks
    (r"moe/router/w$", (None, None)),
    (r"moe/(up|gate)$", (None, "fsdp", "tp")),
    (r"moe/down$", (None, "tp", "fsdp")),
    # mamba
    # in_proj's out dim is a concatenation of segments; mamba-1's cuts are
    # shard-aligned and mamba-2's cost one resharding per layer — still far
    # cheaper than a replicated (B, L, 2·d_inner) activation.
    (r"mix/in_proj/w$", ("fsdp", "tp")),
    (r"mix/x_proj/w$", ("tp", None)),
    (r"mix/dt_proj/w$", (None, "tp")),
    (r"mix/out_proj/w$", ("tp", "fsdp")),
    (r"mix/conv_w$", (None, "tp")),
    (r"mix/conv_b$", ("tp",)),
    (r"mix/A_log$", ("tp", None)),
    (r"mix/(D|dt_bias)$", ("tp",)),
    (r"mix/norm_scale$", ("tp",)),
    # norms and anything small
    (r"(ln\w*|final_norm|norm|ln)/(scale|bias)$", (None,)),
)


def _match_rule(path: str) -> Optional[Tuple]:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            return spec
    return None


def path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    spec = _match_rule(path)
    if spec is None:
        spec = (None,) * len(shape)         # replicate unknowns (safe default)
    # pad leading stacked dims (scan periods / mamba2 A_log heads etc.)
    if len(spec) < len(shape):
        spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    elif len(spec) > len(shape):
        spec = tuple(spec[-len(shape):])
    return fit_spec(spec, shape, mesh)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays or SDS)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: param_pspec(path_str(kp), x.shape, mesh), params)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def batch_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Batch inputs: dim0 = global batch → dp; rest replicated."""
    spec = ("dp",) + (None,) * (len(shape) - 1)
    return fit_spec(spec, shape, mesh)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: batch_pspec(path_str(kp), x.shape, mesh), batch)


# Unstacked rank of each cache leaf kind; extra leading dims are layer
# stacks (scan-over-periods / encdec vmapped layers).
_CACHE_BASE_NDIM = {"k": 4, "v": 4, "S": 4, "h": 3, "conv": 3, "pos": 2,
                    "ks": 3, "vs": 3}


def cache_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                decode: bool = False) -> P:
    """KV/SSM caches: batch → dp; heads/channels → tp, with the trailing
    dim (head_dim / d_inner) as the tp fallback when the head count does
    not divide the model axis (e.g. 8 KV heads on a 16-way axis — grok,
    gemma).  Layer-stacked leaves are detected structurally: rank above the
    leaf kind's base rank = leading stack dims (replicated).

    ``decode=True`` shards KV on the **sequence** dim instead: single-token
    attention then computes logits shard-locally and tree-combines only the
    tiny (m, Σexp, acc) partials — literally the paper's multi-core softmax
    gather (Fig. 5), and it removes the per-layer cache permute that
    head/Dh sharding costs at decode (§Perf pair 3)."""
    leaf = path.rsplit("/", 1)[-1]
    base = _CACHE_BASE_NDIM.get(leaf)
    if base is None:
        off = 1 if "periods" in path.split("/") else 0
    else:
        off = max(len(shape) - base, 0)
    core = len(shape) - off
    spec = [None] * len(shape)
    if core >= 1:
        spec[off] = "dp"
    if decode and leaf in ("k", "v") and core >= 4:
        spec[off + 2] = "tp"       # KV sequence dim
    elif decode and leaf in ("ks", "vs") and core >= 3:
        spec[off + 2] = "tp"       # per-row scales follow their rows
    elif core >= 3:
        spec[off + 1] = "tp"       # heads / channels
        spec[-1] = "tp"            # head-dim fallback (dup dropped by fit)
    return fit_spec(tuple(spec), shape, mesh)


def cache_specs_decode(caches: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: cache_pspec(path_str(kp), x.shape, mesh, decode=True),
        caches)


def cache_specs(caches: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: cache_pspec(path_str(kp), x.shape, mesh), caches)


def pool_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Paged KV *pool* leaves: KV heads → tp, everything else replicated.

    The pool reinterprets the cache batch dim as the page id (serving/
    paged.py), so the serving mesh must NOT shard it — the page table, free
    heap, refcounts and prefix-cache radix tree are host-global and name
    physical pages every device must hold (its head-slice of).  Layout per
    leaf kind (leading dims are period stacks, replicated):

      k/v   (…, P+1, Hkv, ps, Dh) → heads on "model"
      ks/vs (…, P+1, Hkv, ps)     → heads on "model" (scales ride their heads)

    Unlike :func:`cache_pspec` there is no head-dim fallback: the sharded
    ragged step slices q/k/v head *bands* to match the local pool shard, so
    a non-dividing head count must fail engine validation, not silently
    replicate one leaf.
    """
    leaf = path.rsplit("/", 1)[-1]
    base = _CACHE_BASE_NDIM.get(leaf)
    if base is None:
        off = 1 if "periods" in path.split("/") else 0
    else:
        off = max(len(shape) - base, 0)
    spec = [None] * len(shape)
    if leaf in ("k", "v", "ks", "vs") and len(shape) - off >= 2:
        spec[off + 1] = "tp"                     # KV heads
    return fit_spec(tuple(spec), shape, mesh)


def pool_specs(pool: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec for a PagedKVCache pool."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: pool_pspec(path_str(kp), x.shape, mesh), pool)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def shardings_of(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def shard_tree(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """device_put a pytree onto the mesh with the given specs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
        is_leaf=lambda x: isinstance(x, P))
