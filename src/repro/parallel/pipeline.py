"""Pipeline parallelism: GPipe-style stage schedule over a mesh axis.

This is the TPU expression of HASTILY §IV's *inter-layer* fine-grained
pipelining: encoder N's first output vector feeds encoder N+1 immediately.
On a mesh, "vector" becomes "microbatch" and "encoder" becomes "stage": each
device along ``axis`` holds one stage's layers; microbatches flow through
the stage ring via ``ppermute``.  For M microbatches and S stages the bubble
fraction is (S−1)/(M+S−1) — the paper's (N+1)·seqLen fill cost in TPU form
(DESIGN.md §2).

Implementation: ``shard_map`` over ``axis``; each step of the schedule loop
computes the resident stage on its current activation and rotates
activations one stage forward.  Stage s processes microbatch m at step
t = s + m, so the loop runs M + S − 1 steps; outputs are collected on the
last stage and rotated back to stage order at the end.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

Params = Any


def pipeline_apply(stage_fn: Callable[[Params, jax.Array], jax.Array],
                   stage_params: Params, x: jax.Array, mesh: Mesh,
                   axis: str = "pod") -> jax.Array:
    """Run ``stage_fn`` as an S-stage pipeline over mesh ``axis``.

    stage_params: pytree whose leaves have leading dim S (one slice per
    stage, sharded over ``axis``).  x: (M, mb, ...) microbatched input,
    replicated over ``axis``.  Returns (M, mb, ...) outputs.
    """
    s = mesh.shape[axis]
    m = x.shape[0]

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(p_spec, P()), out_specs=P(),
        check=False)
    def run(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # this stage's slice
        stage = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % s) for i in range(s)]      # stage ring
        n_steps = m + s - 1

        def body(carry, t):
            act, outs = carry
            # microbatch index this stage would start at step t
            mb_idx = t - stage
            fresh = jnp.where((mb_idx >= 0) & (mb_idx < m),
                              jnp.clip(mb_idx, 0, m - 1), 0)
            # stage 0 ingests a fresh microbatch; others use the rotated act
            inp = jnp.where(stage == 0, xs[fresh], act)
            active = (mb_idx >= 0) & (mb_idx < m)
            out = stage_fn(params, inp)
            out = jnp.where(active, out, act)
            # last stage emits: store finished microbatch
            done_idx = t - (s - 1)
            emit = (stage == s - 1) & (done_idx >= 0) & (done_idx < m)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[jnp.clip(done_idx, 0, m - 1)].set(out),
                lambda o: o, outs)
            # rotate activations one stage forward
            act_next = jax.lax.ppermute(out, axis, fwd)
            return (act_next, outs), None

        init_act = jnp.zeros_like(xs[0])
        init_out = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(body, (init_act, init_out),
                                    jnp.arange(n_steps))
        # Only the last stage accumulated into ``outs``; everyone else holds
        # zeros, so a psum replicates the result (out_specs=P()).
        return jax.lax.psum(outs, axis)

    return run(stage_params, x)


def stack_stages(layer_params: Params, num_stages: int) -> Params:
    """Regroup a leading layers dim L into (S, L/S) stage slices."""
    def regroup(a):
        l = a.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return a.reshape((num_stages, l // num_stages) + a.shape[1:])
    return jax.tree.map(regroup, layer_params)
