from repro.parallel.sharding import (batch_specs, cache_specs,
                                     cache_specs_decode, fit_spec,
                                     logical_axes, param_pspec, param_specs,
                                     shard_tree, shardings_of)
from repro.parallel.grad_compress import (compress_with_feedback,
                                          compressed_psum, decompress,
                                          feedback_init)
from repro.parallel.pipeline import pipeline_apply, stack_stages
from repro.parallel.ctx import activation_sharding, active_mesh, maybe_shard

__all__ = ["param_specs", "param_pspec", "batch_specs", "cache_specs",
           "cache_specs_decode",
           "fit_spec", "logical_axes", "shard_tree", "shardings_of",
           "compress_with_feedback", "compressed_psum", "decompress",
           "feedback_init", "pipeline_apply", "stack_stages",
           "activation_sharding", "active_mesh", "maybe_shard"]
