"""Version-tolerant shims over jax APIs that drifted across 0.4.x → 0.5+.

Three drift points bite this repo (the container pins jax 0.4.37; the code
was written against newer releases):

- ``jax.shard_map`` is top-level in new jax, ``jax.experimental.shard_map``
  in 0.4.x;
- its replication-check kwarg was renamed ``check_rep`` → ``check_vma``;
- ``jax.make_mesh`` grew an ``axis_types=`` kwarg (with
  ``jax.sharding.AxisType``) that 0.4.x lacks.

Everything here is a thin forwarding wrapper — import from this module
instead of hand-rolling try/excepts at each call site.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.35 exports shard_map at the top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

try:
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x
    AxisType = None

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f: Optional[Callable] = None, *, mesh: Mesh, in_specs: Any,
              out_specs: Any, check: bool = True) -> Callable:
    """``jax.shard_map`` with the check kwarg spelled per installed version.

    Usable directly or as a decorator factory (``f=None``), mirroring the
    real API.  ``check`` maps to ``check_vma`` (new) / ``check_rep`` (0.4.x).
    """
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs,
          _CHECK_KW: check}
    if f is None:
        return lambda g: _shard_map(g, **kw)
    return _shard_map(f, **kw)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` (new) / ``psum(1, axis)`` (0.4.x) inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the version supports them."""
    if AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))
