"""AsyncLMServer: the asyncio front door around ``EngineCore.step()``.

The engine is a library — submit/step/finished.  Serving millions of users
needs a *process*: request intake with admission backpressure, per-token
streaming, cancellation that frees resources immediately, graceful drain.
This module is that process, as one serve loop and one async generator:

    intake queue ──► submit ──► EngineCore.step() ──► stream deltas ──► client
         ▲                          │        ▲                            │
         │ backpressure             │        └── abort (pages freed) ◄────┘
         └── reject / wait          ▼             on cancel/disconnect
                              graceful drain

- **Intake / backpressure** — ``generate()`` validates eagerly (a bad
  request raises :class:`~repro.serving.sampling.InvalidRequest` in the
  client's own context, never mid-serve) and enqueues onto a *bounded*
  queue.  ``admission="wait"`` suspends the client until a slot opens —
  backpressure propagates to the caller; ``admission="reject"`` raises
  :class:`ServerOverloaded` immediately (shed load at the door).
- **The serve loop** — single task, and the only place the engine is
  touched (submit/abort/step are serialized by construction; no locks).
  Each iteration drains intake, processes pending aborts — so a cancelled
  request's pages are free *before* the next step runs — then executes one
  ``engine.step()`` in a worker thread (``asyncio.to_thread``: clients
  keep streaming/connecting while the device works) and flushes new
  tokens to every client's stream.
- **Streaming** — per-token deltas come from ``req.tokens[emitted:safe]``,
  not from ``StepOutput.tokens`` (a speculative step commits several
  tokens at once; the cursor form loses nothing).  ``safe`` holds back any
  suffix that could still complete a stop sequence
  (:func:`~repro.serving.sampling.stop_holdback`) — a streamed token is
  never retracted.
- **Cancellation** — a client breaking out of (or erroring inside) the
  async-for lands in the generator's ``finally``: the uid joins the abort
  set and the loop calls ``EngineCore.abort()`` before its next step —
  scheduler release, prefix-cache publish of full pages, lane freed within
  one step.  Disconnect and explicit cancel are the same path.
- **Shutdown** — ``shutdown(drain=True)`` stops intake and lets resident
  work finish; ``drain=False`` aborts every in-flight client first.  The
  async context manager form does a draining shutdown on exit.

Latency telemetry (TTFT / TPOT / sustained req/s) flows into the engine's
metrics registry (``serving/tracing.py``); :meth:`AsyncLMServer.summary`
is a thin window over it — the nightly serve-loop bench, the ``/metrics``
exposition and ``--metrics-json`` all read the same counters.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import AsyncIterator, Dict, Optional, Set

from repro.serving.api import Request
from repro.serving.sampling import stop_holdback
from repro.serving.tracing import ServingObservability

_DONE = object()          # end-of-stream sentinel on a client's queue


class ServerOverloaded(RuntimeError):
    """Admission rejected: the intake queue is full (``admission="reject"``)."""


class ServerClosed(RuntimeError):
    """The server is shutting down; no new requests are admitted."""


@dataclasses.dataclass
class _Client:
    req: Request
    queue: asyncio.Queue            # int tokens | Exception | _DONE
    submitted_t: float
    first_t: Optional[float] = None
    emitted: int = 0
    cancelled: bool = False


class AsyncLMServer:
    """Asyncio serve loop around an :class:`~repro.serving.core.EngineCore`
    (the engine must support ``abort``; the slot-contiguous fallback engine
    does not — serve it with the sync driver).

    ::

        server = AsyncLMServer(engine, max_waiting=64)
        async with server:
            async for tok in server.generate(req):
                ...                       # break == cancel; pages freed

    ``max_waiting`` bounds the intake queue (requests the engine has not
    yet admitted); ``admission`` picks the backpressure policy: ``"wait"``
    (default) suspends ``generate()`` until a slot opens, ``"reject"``
    raises :class:`ServerOverloaded` at the door.
    """

    def __init__(self, engine, *, max_waiting: int = 64,
                 admission: str = "wait"):
        if admission not in ("wait", "reject"):
            raise ValueError(f"unknown admission policy {admission!r}; "
                             f"expected 'wait' or 'reject'")
        if not hasattr(engine, "abort"):
            raise TypeError("AsyncLMServer needs an engine with abort() — "
                            "EngineCore; the slot ServingEngine cannot "
                            "cancel mid-flight requests")
        self.engine = engine
        self.admission = admission
        self.max_waiting = max_waiting
        self._intake: Optional[asyncio.Queue] = None
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._clients: Dict[int, _Client] = {}
        self._aborts: Set[int] = set()
        self._closing = False
        self.steps = 0
        self.cancelled = 0
        # The engine's observability bundle is the telemetry home; an
        # engine serving with metrics off gets a private (enabled) one so
        # summary() keeps working either way.
        obs = getattr(engine, "obs", None)
        self.obs = (obs if obs is not None and obs.enabled
                    else ServingObservability())
        self._window: Optional[dict] = None     # registry anchor at start()
        self._span_t0: Optional[float] = None   # earliest finished submit
        self._span_t1: Optional[float] = None   # latest finish

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> "AsyncLMServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._intake = asyncio.Queue(maxsize=self.max_waiting)
        self._wake = asyncio.Event()
        self._window = self.obs.server_window()
        self._task = asyncio.create_task(self._serve(), name="lm-serve-loop")
        return self

    async def shutdown(self, drain: bool = True) -> None:
        """Stop the serve loop.  ``drain=True`` finishes resident work
        first (intake closes immediately); ``drain=False`` aborts every
        in-flight client.  Idempotent; re-raises a crashed loop's error."""
        self._closing = True
        if not drain:
            for uid in list(self._clients):
                self._aborts.add(uid)
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def __aenter__(self) -> "AsyncLMServer":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        # On a client-side exception, don't block exit on a full drain.
        await self.shutdown(drain=exc_type is None)

    # ------------------------------------------------------------- clients
    async def generate(self, req: Request) -> AsyncIterator[int]:
        """Submit ``req`` and stream its generated tokens as they commit.

        The stream ends when the request finishes (stop/eos/max_new).
        Closing the generator early — client disconnect, ``break``, task
        cancellation — aborts the request; its lane and pages are free
        before the next engine step."""
        if self._closing:
            raise ServerClosed("server is shutting down")
        if self._task is None:
            raise RuntimeError("server not started (use 'async with' or "
                               "await start())")
        self.engine.validate(req)      # fail in the client's own context
        client = _Client(req=req, queue=asyncio.Queue(),
                         submitted_t=time.perf_counter())
        if self.admission == "reject":
            try:
                self._intake.put_nowait(client)
            except asyncio.QueueFull:
                raise ServerOverloaded(
                    f"intake queue full ({self.max_waiting} waiting)")
        else:
            await self._intake.put(client)     # backpressure: suspend here
        self._wake.set()
        try:
            while True:
                item = await client.queue.get()
                if item is _DONE:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            if not req.done:                   # cancelled / disconnected
                client.cancelled = True
                self._aborts.add(req.uid)
                if self._wake is not None:
                    self._wake.set()

    # ---------------------------------------------------------- serve loop
    def _drain_intake(self) -> None:
        while True:
            try:
                client = self._intake.get_nowait()
            except asyncio.QueueEmpty:
                return
            if client.cancelled:               # gone before admission
                continue
            try:
                self.engine.submit(client.req)
            except Exception as e:             # pragma: no cover - eager
                client.queue.put_nowait(e)     # validation catches these
                continue
            self._clients[client.req.uid] = client

    def _process_aborts(self) -> None:
        while self._aborts:
            uid = self._aborts.pop()
            self.engine.abort(uid)
            client = self._clients.pop(uid, None)
            if client is not None:
                self.cancelled += 1
                self.obs.stream_cancelled()
                client.queue.put_nowait(_DONE)

    def _flush(self) -> None:
        """Push each live request's newly-committed tokens to its client.

        Deltas are cursor-based over ``req.tokens`` (speculative steps
        commit several at once) minus the stop-holdback suffix; a finished
        request's final truncation has already been applied by the engine,
        so everything left streams out, then the end-of-stream sentinel."""
        now = time.perf_counter()
        for uid, client in list(self._clients.items()):
            req = client.req
            safe = (len(req.tokens) if req.done
                    else stop_holdback(req.tokens, req.sampling.stop))
            while client.emitted < safe:
                if client.first_t is None:
                    client.first_t = now
                client.queue.put_nowait(req.tokens[client.emitted])
                client.emitted += 1
            if req.done:
                if client.first_t is not None:
                    self._span_t0 = (client.submitted_t
                                     if self._span_t0 is None
                                     else min(self._span_t0,
                                              client.submitted_t))
                    self._span_t1 = (now if self._span_t1 is None
                                     else max(self._span_t1, now))
                self.obs.stream_finished(client.submitted_t, client.first_t,
                                         now, client.emitted)
                client.queue.put_nowait(_DONE)
                del self._clients[uid]

    async def _serve(self) -> None:
        try:
            while True:
                self._drain_intake()
                self._process_aborts()
                if not self.engine.scheduler.has_work():
                    if (self._closing and self._intake.empty()
                            and not self._aborts):
                        return
                    self._wake.clear()
                    # re-check after clear (lost-wakeup race), then park
                    if (self._intake.empty() and not self._aborts
                            and not self._closing):
                        await self._wake.wait()
                    continue
                # One engine step off-loop: intake/cancel keep flowing
                # while the device works.  The loop is the only engine
                # toucher, so submit/abort/step are serialized for free.
                await asyncio.to_thread(self.engine.step)
                self.steps += 1
                self._flush()
        except BaseException as e:
            for client in self._clients.values():
                client.queue.put_nowait(e)
            self._clients.clear()
            raise

    # ------------------------------------------------------------ telemetry
    def summary(self) -> dict:
        """Latency aggregate over this server instance's finished requests
        — a thin window over the metrics registry (sustained req/s over
        the serving span, TTFT p50/p99 submit → first streamed token, TPOT
        mean inter-token time after the first).  The same counters feed
        ``/metrics`` and ``--metrics-json``; nothing is recomputed here."""
        return self.obs.server_summary(
            self._window, steps=self.steps, cancelled=self.cancelled,
            span=(self._span_t0, self._span_t1))
