"""Request-lifecycle spans, step trace ring, and the retrace sentinel.

``ServingObservability`` is the one object threaded through the serving
stack (EngineCore, Scheduler, PagedKVCache, RadixPrefixCache, the
n-gram proposer, AsyncLMServer).  It owns

* a :class:`~repro.serving.metrics.MetricsRegistry` (the single source
  of truth for every counter/gauge/histogram the stack reports),
* a :class:`RequestTracer` recording one span per request
  (submitted → admitted → first_token → finished/aborted, with
  preemption/resume, prefix-hit, draft accept/reject, and CoW events
  attached),
* a :class:`StepTraceRing` of the scheduler's last N step decisions
  (bucket width, table width, live/padded rows, trimmed drafts, pool
  occupancy, cache reclaimable pages), and
* the **retrace sentinel**: the jitted step closures already bump a
  python-side counter *inside* the traced function body — a side effect
  that runs exactly when XLA traces, i.e. on every jit-cache miss.
  ``step_traced()`` mirrors that into ``step_traces_total`` always and
  into ``step_retraces_total`` only after :meth:`mark_warm` — so the
  PR 8 class of bug (a mid-traffic table-width shrink forcing a ~2 s
  XLA stall) is a metric, not an archaeology project.

Every hook early-returns when ``enabled=False`` (metrics-off engines
for the overhead A/B) and everything stays host-side, off the jitted
path.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .metrics import Histogram, MetricsRegistry

__all__ = [
    "SpanEvent",
    "RequestSpan",
    "RequestTracer",
    "StepTraceRing",
    "ServingObservability",
]


# ------------------------------------------------------------- spans --

@dataclass
class SpanEvent:
    name: str
    t: float
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class RequestSpan:
    uid: int
    start_t: float
    events: List[SpanEvent] = field(default_factory=list)
    status: Optional[str] = None          # "finished" | "aborted" | ...
    end_t: Optional[float] = None

    @property
    def open(self) -> bool:
        return self.status is None

    def event_names(self) -> List[str]:
        return [e.name for e in self.events]

    def first(self, name: str) -> Optional[SpanEvent]:
        for e in self.events:
            if e.name == name:
                return e
        return None

    def duration_ms(self) -> float:
        end = self.end_t if self.end_t is not None else self.start_t
        return (end - self.start_t) * 1e3


class RequestTracer:
    """One span per request uid; bounded deque of closed spans."""

    def __init__(self, max_finished: int = 1024, clock=time.perf_counter):
        self.clock = clock
        self._open: Dict[int, RequestSpan] = {}
        self.finished: deque = deque(maxlen=max_finished)

    def begin(self, uid: int, **attrs) -> RequestSpan:
        stale = self._open.pop(uid, None)
        if stale is not None:            # uid reuse with a leaked span
            stale.status = "orphaned"
            stale.end_t = self.clock()
            self.finished.append(stale)
        now = self.clock()
        span = RequestSpan(uid=uid, start_t=now)
        span.events.append(SpanEvent("submitted", now, dict(attrs)))
        self._open[uid] = span
        return span

    def event(self, uid: int, name: str, **attrs) -> None:
        span = self._open.get(uid)
        if span is not None:             # unknown uid: deliberate no-op
            span.events.append(SpanEvent(name, self.clock(), dict(attrs)))

    def end(self, uid: int, status: str, **attrs) -> Optional[RequestSpan]:
        span = self._open.pop(uid, None)
        if span is None:
            return None
        now = self.clock()
        span.events.append(SpanEvent(status, now, dict(attrs)))
        span.status = status
        span.end_t = now
        self.finished.append(span)
        return span

    def open_spans(self) -> Dict[int, RequestSpan]:
        return dict(self._open)

    def span(self, uid: int) -> Optional[RequestSpan]:
        """The open span for uid, else the most recent closed one."""
        got = self._open.get(uid)
        if got is not None:
            return got
        for span in reversed(self.finished):
            if span.uid == uid:
                return span
        return None


class StepTraceRing:
    """Bounded ring of per-step scheduler-decision records (dicts)."""

    def __init__(self, capacity: int = 512):
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)

    def append(self, record: Dict[str, object]) -> None:
        self._ring.append(record)

    def records(self) -> List[Dict[str, object]]:
        return list(self._ring)

    def last(self) -> Optional[Dict[str, object]]:
        return self._ring[-1] if self._ring else None

    def __len__(self) -> int:
        return len(self._ring)


# ----------------------------------------------------- observability --

class ServingObservability:
    """The bundle threaded through the serving stack.

    All mutating hooks early-return when ``enabled`` is False; family
    handles are pre-bound in ``__init__`` so the hot hooks are attribute
    bumps, not dict lookups.
    """

    def __init__(self, enabled: bool = True,
                 registry: Optional[MetricsRegistry] = None,
                 ring_capacity: int = 512):
        self.enabled = enabled
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = RequestTracer()
        self.ring = StepTraceRing(ring_capacity)
        self.warm = False
        self._profiler: Optional[dict] = None

        r = self.registry
        # -- step/engine counters
        self.c_steps = r.counter(
            "steps_total", "engine steps executed")
        self.c_mixed_steps = r.counter(
            "mixed_steps_total", "steps co-batching prefill and decode")
        self.c_traces = r.counter(
            "step_traces_total", "jit traces of the step fn (lifetime)")
        self.c_retraces = r.counter(
            "step_retraces_total", "step fn traces after mark_warm()")
        self.c_prefill_toks = r.counter(
            "prefill_tokens_total", "prompt tokens processed")
        self.c_decode_toks = r.counter(
            "decode_tokens_total", "decode tokens processed")
        self.c_live_rows = r.counter(
            "live_rows_total", "live token rows packed into steps")
        self.c_padded_rows = r.counter(
            "padded_rows_total", "padded stream width summed over steps")
        self.c_tokens_out = r.counter(
            "tokens_generated_total", "tokens committed to requests")
        self.c_trim_prefill = r.counter(
            "trimmed_prefill_tokens_total",
            "prefill tokens deferred by bucket trimming")
        self.c_trim_drafts = r.counter(
            "spec_trimmed_draft_tokens_total",
            "draft tokens dropped by trim/degrade before packing")
        # -- request lifecycle
        self.c_submitted = r.counter(
            "requests_submitted_total", "requests entering the scheduler")
        self.c_admitted = r.counter(
            "requests_admitted_total", "waiting->running admissions")
        self.c_resumed = r.counter(
            "requests_resumed_total", "preempted->running resumptions")
        self.c_finished = r.counter(
            "requests_finished_total", "requests completed")
        self.c_aborted = r.counter(
            "requests_aborted_total", "requests aborted/cancelled")
        self.c_preempted = r.counter(
            "preemptions_total", "requests preempted by page pressure")
        # -- speculative decoding
        self.c_drafted = r.counter(
            "spec_drafted_tokens_total", "draft tokens entering verify")
        self.c_accepted = r.counter(
            "spec_accepted_tokens_total", "draft tokens accepted")
        self.c_spec_steps = r.counter(
            "spec_steps_total", "steps that verified at least one draft")
        self.c_proposals = r.counter(
            "spec_proposals_total", "proposer calls that drafted tokens")
        self.c_proposed = r.counter(
            "spec_proposed_tokens_total", "tokens drafted by the proposer")
        # -- prefix cache / pages
        self.c_prefix_lookups = r.counter(
            "prefix_lookups_total", "prefix-cache lookups at admission")
        self.c_prefix_lookup_toks = r.counter(
            "prefix_lookup_tokens_total", "prompt tokens offered for reuse")
        self.c_prefix_hits = r.counter(
            "prefix_hits_total", "lookups that matched cached pages")
        self.c_prefix_hit_toks = r.counter(
            "prefix_hit_tokens_total", "prompt tokens served from cache")
        self.c_prefix_shared = r.counter(
            "prefix_shared_page_grants_total", "cached pages granted shared")
        self.c_prefix_evicted = r.counter(
            "prefix_evicted_pages_total", "cached pages evicted")
        self.c_cow = r.counter(
            "cow_copies_total", "copy-on-write page copies")
        # -- streaming front door
        self.c_stream_requests = r.counter(
            "stream_requests_total", "streamed requests finished")
        self.c_stream_cancelled = r.counter(
            "stream_cancelled_total", "streamed requests cancelled")
        self.c_stream_tokens = r.counter(
            "stream_tokens_total", "tokens emitted to streams")
        # -- gauges
        self.g_pool_in_use = r.gauge(
            "pool_pages_in_use", "page-pool pages currently referenced")
        self.g_pool_free = r.gauge(
            "pool_pages_free", "page-pool pages on the free heap")
        self.g_pool_peak = r.gauge(
            "pool_pages_in_use_peak", "high-water pages in use")
        self.g_waiting = r.gauge(
            "scheduler_waiting", "requests queued for admission")
        self.g_running = r.gauge(
            "scheduler_running", "requests resident in lanes")
        self.g_table_pages = r.gauge(
            "step_table_pages", "page-table width of the last step")
        self.g_cached_pages = r.gauge(
            "prefix_cached_pages", "pages held by the prefix cache")
        self.g_reclaimable = r.gauge(
            "prefix_reclaimable_pages", "cache-only pages reclaimable")
        self.g_mesh = r.gauge(
            "mesh_devices", "tensor-parallel mesh size")
        self.g_coll_per_tok = r.gauge(
            "collective_bytes_per_token",
            "analytic per-device all-gather bytes per packed token")
        self.g_coll_per_step = r.gauge(
            "collective_bytes_per_step",
            "measured per-device collective bytes per step (from HLO)")
        # -- histograms
        self.h_step_ms = r.histogram(
            "step_latency_ms", "wall time of EngineCore.step()")
        self.h_ttft_ms = r.histogram(
            "request_ttft_ms", "submit to first committed token")
        self.h_tpot_ms = r.histogram(
            "request_tpot_ms", "mean inter-token time per finished request")
        self.h_stream_ttft_ms = r.histogram(
            "stream_ttft_ms", "server submit to first streamed token")
        self.h_stream_tpot_ms = r.histogram(
            "stream_tpot_ms", "server mean inter-token time per stream")

    # ------------------------------------------------- retrace sentinel --
    def step_traced(self) -> None:
        """Called from *inside* the jitted step closures: runs only when
        XLA traces (a jit-cache miss), i.e. once per new input shape."""
        if not self.enabled:
            return
        self.c_traces.inc()
        if self.warm:
            self.c_retraces.inc()

    def mark_warm(self) -> None:
        """Every trace after this counts as a retrace (a bug signal)."""
        self.warm = True

    # ---------------------------------------------------- request hooks --
    def request_submitted(self, uid: int, prompt_len: int = 0,
                          max_new: int = 0) -> None:
        if not self.enabled:
            return
        self.c_submitted.inc()
        self.tracer.begin(uid, prompt_len=prompt_len, max_new=max_new)

    def request_admitted(self, uid: int, hit_tokens: int = 0,
                         resumed: bool = False) -> None:
        if not self.enabled:
            return
        if resumed:
            self.c_resumed.inc()
            self.tracer.event(uid, "resumed")
        else:
            self.c_admitted.inc()
            attrs = {"prefix_hit_tokens": hit_tokens} if hit_tokens else {}
            self.tracer.event(uid, "admitted", **attrs)

    def request_preempted(self, uid: int) -> None:
        if not self.enabled:
            return
        self.c_preempted.inc()
        self.tracer.event(uid, "preempted")

    def request_finished(self, uid: int, aborted: bool = False,
                         generated: int = 0) -> None:
        if not self.enabled:
            return
        if aborted:
            self.c_aborted.inc()
        else:
            self.c_finished.inc()
        span = self.tracer.end(uid, "aborted" if aborted else "finished",
                               generated=generated)
        if span is not None and not aborted and generated > 1:
            first = span.first("first_token")
            if first is not None:
                self.h_tpot_ms.observe(
                    (span.end_t - first.t) * 1e3 / (generated - 1))

    def tokens_committed(self, uid: int, n: int, first: bool) -> None:
        if not self.enabled or n <= 0:
            return
        self.c_tokens_out.inc(n)
        if first:
            self.tracer.event(uid, "first_token")
            span = self.tracer.span(uid)
            if span is not None and span.open:
                self.h_ttft_ms.observe(
                    (span.events[-1].t - span.start_t) * 1e3)

    def spec_proposed(self, tokens: int) -> None:
        if not self.enabled:
            return
        self.c_proposals.inc()
        self.c_proposed.inc(tokens)

    def spec_verify(self, uid: int, drafted: int, accepted: int) -> None:
        if not self.enabled or drafted <= 0:
            return
        self.tracer.event(uid, "spec_verify",
                          drafted=drafted, accepted=accepted)

    def cow_copy(self) -> None:
        """Counter-only: PagedKVCache.cow() calls this for every copy."""
        if not self.enabled:
            return
        self.c_cow.inc()

    def request_cow(self, uid: int) -> None:
        """Span-only: the scheduler attributes a CoW to a request."""
        if not self.enabled:
            return
        self.tracer.event(uid, "cow_copy")

    # ------------------------------------------------ prefix-cache hooks --
    def prefix_lookup(self, tokens: int, hit_tokens: int,
                      shared_pages: int) -> None:
        if not self.enabled:
            return
        self.c_prefix_lookups.inc()
        self.c_prefix_lookup_toks.inc(tokens)
        if hit_tokens:
            self.c_prefix_hits.inc()
            self.c_prefix_hit_toks.inc(hit_tokens)
            self.c_prefix_shared.inc(shared_pages)

    def prefix_evicted(self, pages: int = 1) -> None:
        if not self.enabled:
            return
        self.c_prefix_evicted.inc(pages)

    # ------------------------------------------------------- step hook --
    def record_step(self, out, *, dur_ms: float, sched, kv,
                    cache=None, table_pages: int = 0,
                    trimmed_prefill: int = 0, trimmed_drafts: int = 0,
                    width: int = 0) -> None:
        """Called once per EngineCore.step() with the StepOutput."""
        if self._profiler is not None:
            self._profiler_tick()
        if not self.enabled:
            return
        self.c_steps.inc()
        if out.prefill_tokens and out.decode_tokens:
            self.c_mixed_steps.inc()
        self.c_prefill_toks.inc(out.prefill_tokens)
        self.c_decode_toks.inc(out.decode_tokens)
        self.c_live_rows.inc(out.live_rows)
        self.c_padded_rows.inc(out.padded_rows)
        if out.drafted_tokens:
            self.c_drafted.inc(out.drafted_tokens)
            self.c_accepted.inc(out.accepted_tokens)
            self.c_spec_steps.inc()
        if trimmed_prefill:
            self.c_trim_prefill.inc(trimmed_prefill)
        if trimmed_drafts:
            self.c_trim_drafts.inc(trimmed_drafts)
        self.h_step_ms.observe(dur_ms)

        in_use = kv.num_pages - len(kv.free)
        self.g_pool_in_use.set(in_use)
        self.g_pool_free.set(len(kv.free))
        self.g_pool_peak.set_max(in_use)
        self.g_waiting.set(len(sched.waiting))
        self.g_running.set(len(sched.running))
        self.g_table_pages.set(table_pages)
        reclaimable = 0
        if cache is not None:
            self.g_cached_pages.set(cache.cached_pages)
            reclaimable = cache.reclaimable_pages
            self.g_reclaimable.set(reclaimable)
        self.ring.append({
            "step": int(self.c_steps.value()),
            "width": width,
            "table_pages": table_pages,
            "live_rows": out.live_rows,
            "padded_rows": out.padded_rows,
            "prefill_tokens": out.prefill_tokens,
            "decode_tokens": out.decode_tokens,
            "drafted_tokens": out.drafted_tokens,
            "accepted_tokens": out.accepted_tokens,
            "trimmed_prefill_tokens": trimmed_prefill,
            "trimmed_draft_tokens": trimmed_drafts,
            "pool_pages_in_use": in_use,
            "cache_reclaimable_pages": reclaimable,
            "dur_ms": dur_ms,
        })

    def reset_peaks(self) -> None:
        """Re-anchor high-water gauges (bench passes call this)."""
        self.g_pool_peak.set(self.g_pool_in_use.value())

    # ---------------------------------------------------- jax profiler --
    def arm_profiler(self, steps: int, logdir: str) -> None:
        """Opt-in: capture a ``jax.profiler`` trace window around the
        next ``steps`` engine steps, written to ``logdir``."""
        self._profiler = {"left": int(steps), "dir": logdir, "on": False}

    def _profiler_tick(self) -> None:
        p = self._profiler
        if p is None:
            return
        if not p["on"]:
            try:
                import jax
                jax.profiler.start_trace(p["dir"])
                p["on"] = True
            except Exception:
                self._profiler = None
                return
        p["left"] -= 1
        if p["left"] <= 0:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._profiler = None

    # ------------------------------------------------- summary windows --
    def engine_window(self) -> Dict[str, int]:
        """Anchor for a per-pass latency window over the engine-side
        TTFT/TPOT histograms (bench batch arms)."""
        return {"ttft_n": self.h_ttft_ms.count(),
                "tpot_n": self.h_tpot_ms.count()}

    def engine_latency_summary(self, window: Dict[str, int]) -> Dict[str, float]:
        skip_t, skip_p = window["ttft_n"], window["tpot_n"]
        return {
            "ttft_ms_p50": self.h_ttft_ms.percentile(0.50, skip=skip_t),
            "ttft_ms_p99": self.h_ttft_ms.percentile(0.99, skip=skip_t),
            "tpot_ms": self.h_tpot_ms.mean(skip=skip_p),
        }

    def server_window(self) -> Dict[str, float]:
        """Anchor for a per-server-instance summary window."""
        return {"requests": self.c_stream_requests.value(),
                "tokens": self.c_stream_tokens.value(),
                "ttft_n": self.h_stream_ttft_ms.count(),
                "tpot_n": self.h_stream_tpot_ms.count()}

    def stream_finished(self, submitted_t: float, first_t: Optional[float],
                        end_t: float, emitted: int) -> None:
        """Server-side terminal accounting for one finished stream."""
        if not self.enabled or first_t is None:
            return
        self.c_stream_requests.inc()
        self.c_stream_tokens.inc(emitted)
        self.h_stream_ttft_ms.observe((first_t - submitted_t) * 1e3)
        if emitted > 1:
            self.h_stream_tpot_ms.observe(
                (end_t - first_t) * 1e3 / (emitted - 1))

    def stream_cancelled(self) -> None:
        if not self.enabled:
            return
        self.c_stream_cancelled.inc()

    def server_summary(self, window: Optional[Dict[str, float]],
                       *, steps: int, cancelled: int,
                       span: Tuple[Optional[float], Optional[float]],
                       ) -> Dict[str, float]:
        """The registry view behind ``AsyncLMServer.summary()``."""
        w = window or {"requests": 0, "tokens": 0, "ttft_n": 0, "tpot_n": 0}
        n = int(self.c_stream_requests.value() - w["requests"])
        if n == 0:
            return {"requests": 0, "cancelled": cancelled, "steps": steps}
        t0, t1 = span
        elapsed = (t1 - t0) if (t0 is not None and t1 is not None) else 0.0
        return {
            "requests": n,
            "cancelled": cancelled,
            "steps": steps,
            "req_s": n / elapsed if elapsed > 0 else float("inf"),
            "ttft_ms_p50": self.h_stream_ttft_ms.percentile(
                0.50, skip=int(w["ttft_n"])),
            "ttft_ms_p99": self.h_stream_ttft_ms.percentile(
                0.99, skip=int(w["ttft_n"])),
            "tpot_ms": self.h_stream_tpot_ms.mean(skip=int(w["tpot_n"])),
            "tokens": int(self.c_stream_tokens.value() - w["tokens"]),
        }

    def spec_window(self) -> Dict[str, dict]:
        return self.registry.snapshot()

    def spec_summary(self, since: Dict[str, dict]) -> Dict[str, float]:
        d = self.registry.delta(since)
        drafted = d.get("spec_drafted_tokens_total", 0)
        accepted = d.get("spec_accepted_tokens_total", 0)
        spec_steps = d.get("spec_steps_total", 0)
        return {
            "drafted_tokens": int(drafted),
            "accepted_tokens": int(accepted),
            "spec_steps": int(spec_steps),
            "acceptance": accepted / drafted if drafted else 0.0,
            "accepted_per_spec_step":
                accepted / spec_steps if spec_steps else 0.0,
        }
