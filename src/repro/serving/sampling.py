"""Per-request sampling: :class:`SamplingParams` + the batched in-step sampler.

The paper's thesis is that softmax — max, LUT-exp, sum, normalize — deserves
dedicated compute (UCLMs, §III-B).  Serving has a second softmax besides
attention: the sampling distribution over the vocabulary.  This module puts
that distribution *inside* the jitted ragged step, built from the same LUT
machinery (``core/lut_exp`` / ``core/lut_softmax``):

    temperature-scale → top-k mask → top-p (nucleus) mask over the
    LUT-softmax probabilities → Gumbel-max categorical draw over the
    LUT log-softmax scores

One vectorized pass over the ragged step's ``last_idx`` logits ``(lanes, V)``
— no host round-trip between logits and token.  Every parameter rides in as
*data* (per-lane arrays, never static args), so sampling params cannot cause
a retrace: the O(1)-compile guarantee of the ragged step survives unchanged.

Determinism contracts
---------------------
- **Greedy is bit-exact**: a temperature ≤ 0 lane reproduces the serving
  stack's lowest-index tie-break (``core.greedy_token``) exactly — the
  speculative verify rule and every cross-engine equivalence suite survive.
- **Batch-invariant PRNG**: lane ``i``'s draw is a pure function of its
  request's ``(seed, #generated-tokens)`` — ``fold_in(PRNGKey(seed), n)`` —
  never of the lane index, the co-batched traffic, or any engine-global key.
  A request's token stream is identical whether it runs alone, shares a step
  with seven neighbours, or resumes after preemption.  (This replaces the
  PR-2/PR-3 per-engine ``self.key`` that every sampled lane advanced: under
  that scheme a stream depended on every other request ever served.  The old
  host path survives only as :func:`sample_row`, the single-lane oracle.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut_exp import lut_exp
from repro.core.lut_softmax import NEG_INF, lut_log_softmax, lut_softmax


class InvalidRequest(ValueError):
    """A request that can never be served correctly, rejected at
    construction/submit (the PR-3 empty-prompt rule, generalised: never
    wedge a lane on bad input).  ``field`` names the offending parameter so
    front doors can map the rejection to a structured client error."""

    def __init__(self, field: str, detail: str, uid=None):
        self.field = field
        self.uid = uid
        who = f"request {uid}: " if uid is not None else ""
        super().__init__(f"{who}invalid {field}: {detail}")


def _as_stop(stop) -> Tuple[Tuple[int, ...], ...]:
    seqs = []
    for s in stop:
        if isinstance(s, (int, np.integer)):
            s = (s,)
        seq = tuple(int(t) for t in s)
        if not seq:
            raise InvalidRequest("stop", "empty stop sequence")
        if any(t < 0 for t in seq):
            raise InvalidRequest("stop", f"negative token id in {seq}")
        seqs.append(seq)
    return tuple(seqs)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling record, validated at construction.

    ``temperature ≤ 0`` means greedy (lowest-index tie-break).  ``top_k`` /
    ``top_p`` of ``None`` disable the respective mask.  ``seed`` (default 0)
    roots the request's private PRNG stream; two requests with the same
    seed, prompt and params produce the same tokens wherever they run.
    ``stop`` is a tuple of stop sequences (token-id tuples; a bare int is a
    one-token sequence): generation finishes when the generated tokens end
    with one, and the match is truncated from the output.  ``max_tokens``
    caps generation (folded into ``Request.max_new`` as the min)."""
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    seed: Optional[int] = None
    stop: Tuple[Tuple[int, ...], ...] = ()
    max_tokens: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0 and self.seed is not None:
            raise InvalidRequest(
                "temperature",
                f"negative temperature ({self.temperature}) is greedy — a "
                f"seed ({self.seed}) would never be used")
        if self.top_k is not None and self.top_k <= 0:
            raise InvalidRequest("top_k", f"must be >= 1, got {self.top_k}")
        if self.top_p is not None and not 0.0 < self.top_p <= 1.0:
            raise InvalidRequest("top_p",
                                 f"must be in (0, 1], got {self.top_p}")
        if self.seed is not None and not 0 <= self.seed < 2 ** 32:
            raise InvalidRequest("seed",
                                 f"must be a uint32, got {self.seed}")
        if self.max_tokens is not None and self.max_tokens <= 0:
            raise InvalidRequest("max_tokens",
                                 f"must be >= 1, got {self.max_tokens}")
        object.__setattr__(self, "stop", _as_stop(self.stop))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def validate_stop_tokens(params: SamplingParams, vocab_size: int,
                         uid=None) -> None:
    """Submit-time half of stop validation: token ids must be inside the
    model's vocab (only the engine knows the vocab; everything else is
    checked at construction)."""
    for s in params.stop:
        bad = [t for t in s if t >= vocab_size]
        if bad:
            raise InvalidRequest(
                "stop", f"token ids {bad} outside vocab of {vocab_size}",
                uid=uid)


# ----------------------------------------------------------- stop matching
def stop_hit(tokens: Sequence[int], stop: Tuple[Tuple[int, ...], ...]
             ) -> Optional[int]:
    """If the generated ``tokens`` end with a stop sequence, return the
    truncation point (index of the match's first token); else None.  Called
    after every committed token, so a stop completed mid-way through a
    multi-token speculative commit — or across step/chunk boundaries — is
    caught at exactly the token that completes it."""
    n = len(tokens)
    for s in stop:
        ls = len(s)
        if n >= ls and tuple(tokens[n - ls:]) == s:
            return n - ls
    return None


def stop_holdback(tokens: Sequence[int], stop: Tuple[Tuple[int, ...], ...]
                  ) -> int:
    """How many of ``tokens`` are safe to stream to a client: everything
    except the longest suffix that is a proper prefix of some stop sequence
    (it might still complete next step, and a streamed token cannot be
    retracted).  Single-token stop sequences hold nothing back — a hit
    truncates before the engine ever reports the token."""
    n = len(tokens)
    hold = 0
    for s in stop:
        for length in range(min(len(s) - 1, n), 0, -1):
            if tuple(tokens[n - length:]) == s[:length]:
                hold = max(hold, length)
                break
    return n - hold


# ------------------------------------------------------- in-step sampling
def greedy_rows(logits: jax.Array) -> jax.Array:
    """(..., V) → (...,) greedy picks, *lowest* index among joint maxima —
    the exact ``core.greedy_token`` math, batched.  ``max`` is an exact
    float op, so this agrees bit-for-bit with the host-side form on the
    same logits (the speculative verify rule depends on it)."""
    v = logits.shape[-1]
    iota = jnp.arange(v, dtype=jnp.int32)
    hit = logits == jnp.max(logits, axis=-1, keepdims=True)
    return jnp.min(jnp.where(hit, iota, v), axis=-1).astype(jnp.int32)


def _request_keys(seed: jax.Array, counter: jax.Array) -> jax.Array:
    """Per-lane PRNG keys: ``fold_in(PRNGKey(seed), counter)``.  The only
    inputs are the request's own seed and its generated-token count — the
    batch-invariance root (see module doc)."""
    def one(s, n):
        return jax.random.fold_in(jax.random.PRNGKey(s), n)
    return jax.vmap(one)(jnp.asarray(seed, jnp.uint32),
                         jnp.asarray(counter, jnp.uint32))


def sample_rows(logits: jax.Array, temperature: jax.Array,
                top_k: jax.Array, top_p: jax.Array, seed: jax.Array,
                counter: jax.Array, *, exp_fn=lut_exp) -> jax.Array:
    """The batched sampling kernel: (N, V) logits + per-row params → (N,)
    int32 tokens, entirely in-graph (jit/trace safe; every param is data).

    temperature ≤ 0 rows take the greedy pick; the full pipeline for the
    rest is temperature-scale → top-k → top-p over the LUT-softmax
    distribution → Gumbel-max argmax over the LUT log-softmax scores
    (adding per-row Gumbel noise to log-probs and taking argmax IS a
    categorical draw).  ``top_k == 0`` / ``top_p == 1`` disable the masks.
    A ``lax.cond`` skips the whole pipeline when no row needs it, so
    all-greedy steps (the common serving case, and every speculative
    verify row) pay only the argmax they always did."""
    logits = jnp.asarray(logits, jnp.float32)
    n, v = logits.shape
    temperature = jnp.asarray(temperature, jnp.float32)
    greedy = greedy_rows(logits)

    def drawn(_):
        t = jnp.where(temperature > 0.0, temperature, 1.0)[:, None]
        # Max-shift BEFORE the divide: raw logits / t overflows to ±inf as
        # t → 0+, and a non-finite score poisons lut_log_softmax.  Shifted
        # scores live in [-big, 0]; any -inf from the divide itself is
        # clamped to NEG_INF.  The shift is a per-row monotone map, so
        # top-k thresholds, nucleus order and the greedy pick are the same
        # token sets.
        x = jnp.maximum(
            (logits - jnp.max(logits, axis=-1, keepdims=True)) / t, NEG_INF)
        # top-k: keep the k largest logits (k-th-largest threshold);
        # k ≥ V keeps every token — bit-identical to no mask at all
        kth = jnp.take_along_axis(
            jnp.sort(x, axis=-1),
            (v - jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v))[:, None],
            axis=-1)
        x = jnp.where(x >= kth, x, NEG_INF)
        # top-p: smallest prefix of the sorted LUT-softmax distribution
        # with mass ≥ p (a token survives while the mass strictly before
        # it is < p, so the head token always does).  p == 1 must keep the
        # whole vocabulary: the cumulative sum's float rounding can reach
        # 1.0 a couple of tokens early, so the disable value is tested
        # explicitly instead of through the mass comparison.
        order = jnp.argsort(-x, axis=-1)
        probs = jnp.take_along_axis(lut_softmax(x, axis=-1, exp_fn=exp_fn),
                                    order, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        p = jnp.clip(jnp.asarray(top_p, jnp.float32), 0.0, 1.0)[:, None]
        keep_sorted = ((csum - probs) < p) | (p >= 1.0)
        keep = jnp.zeros((n, v), bool).at[
            jnp.arange(n)[:, None], order].set(keep_sorted)
        # Gumbel-max categorical over the LUT log-softmax scores, one
        # private key per request (never a shared stream)
        scores = lut_log_softmax(x, axis=-1, where=keep, exp_fn=exp_fn)
        g = jax.vmap(lambda key: jax.random.gumbel(key, (v,), jnp.float32))(
            _request_keys(seed, counter))
        pick = jnp.argmax(scores + g, axis=-1).astype(jnp.int32)
        return jnp.where(temperature > 0.0, pick, greedy)

    return jax.lax.cond(jnp.any(temperature > 0.0), drawn,
                        lambda _: greedy, None)


def sample_in_step(logits: jax.Array, *, temperature: jax.Array,
                   top_k: jax.Array, top_p: jax.Array, seed: jax.Array,
                   counter: jax.Array, exp_fn=lut_exp) -> jax.Array:
    """The ragged step's sampling region (see ``models.lm.lm_step_ragged``).

    ``(lanes, V)`` last-idx logits → ``(lanes,)`` tokens.  The speculative
    form ``(lanes, 1+k, V)`` → ``(lanes, 1+k)``: row 0 samples with the
    lane's params, rows ≥ 1 are forced greedy — they are the verify rows,
    and the acceptance rule is argmax equality (the proposer only drafts
    for greedy lanes, so row 0 of a drafting lane is greedy too)."""
    if logits.ndim == 2:
        return sample_rows(logits, temperature, top_k, top_p, seed, counter,
                           exp_fn=exp_fn)
    lanes, r, v = logits.shape
    col0 = jnp.arange(r, dtype=jnp.int32)[None, :] == 0
    t = jnp.where(col0, jnp.asarray(temperature, jnp.float32)[:, None],
                  0.0).reshape(-1)
    rep = lambda a: jnp.repeat(jnp.asarray(a), r, axis=0)   # noqa: E731
    toks = sample_rows(logits.reshape(lanes * r, v), t, rep(top_k),
                       rep(top_p), rep(seed), rep(counter), exp_fn=exp_fn)
    return toks.reshape(lanes, r)


_jit_sample_rows = jax.jit(sample_rows)


def sample_row(logits_row: jax.Array, params: SamplingParams,
               n_generated: int) -> int:
    """Single-lane host oracle: the exact in-step kernel on one (1, V) row.

    This is what remains of the old host sampling path — the padded oracle
    mode and the slot engine draw through it, so every engine shares one
    sampling semantics (and the same per-request keys: temperature > 0
    streams agree across engines up to logit-level float drift)."""
    out = _jit_sample_rows(
        jnp.asarray(logits_row, jnp.float32)[None, :],
        jnp.asarray([params.temperature], jnp.float32),
        jnp.asarray([params.top_k or 0], jnp.int32),
        jnp.asarray([1.0 if params.top_p is None else params.top_p],
                    jnp.float32),
        jnp.asarray([params.seed or 0], jnp.uint32),
        jnp.asarray([n_generated], jnp.int32))
    return int(out[0])
