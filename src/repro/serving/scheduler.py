"""Continuous-batching scheduler: requests → per-step chunk plans.

One rule unifies every serving phase: a request is a cursor (``rows``) into
its stream of known tokens (prompt ⊕ generated).  Each step the scheduler
grants a resident request the next ``q_len = min(chunk, remaining, budget)``
tokens of that stream; the engine writes their KV rows through the page
table and samples a new token exactly when the cursor reaches the end of
the stream.  Prompt prefill is the cursor sweeping the prompt in fixed-size
chunks; decode is the degenerate chunk of one; resuming a preempted request
is the same sweep over prompt ⊕ already-generated tokens (recompute
preemption — deterministic greedy regenerates the identical suffix).  There
is no separate prefill entry point left to schedule.

Policy
------
- **FCFS admission** against the page-pool budget: the waiting queue is
  ordered by arrival ticket; the head is admitted when a lane is free and
  the pool can hold its *known* tokens (its generation growth is allocated
  lazily, page by page).
- **Token-budget fairness** (``step_tokens``): decode lanes are planned
  first — one token each, so prefill bursts never starve resident decodes —
  then prefill lanes split the remaining budget into chunks, oldest first.
- **Preemption by eviction**: pages are granted in strict ticket order; when
  the pool runs dry the *youngest* resident request is evicted — its pages
  are released (refcount-aware: a page another request or the prefix cache
  still references survives the eviction — only its exclusive pages reach
  the free heap), its cursor rewinds to zero, and it re-enters the waiting
  queue (by its original ticket) to be replayed later.  The oldest resident
  request can always evict its way to the whole pool, so progress is
  guaranteed as long as any single request fits (checked at submit).
- **Prefix reuse** (optional ``prefix_cache``): admission probes the radix
  cache with the request's known tokens; the hit prefix's resident pages
  are *granted shared* and the cursor starts at the first cold token, so
  chunked prefill streams only what the cache misses.  Full pages are
  published back into the cache on completion *and* on eviction (an evicted
  request usually resumes by cache hit instead of recompute), and a grant
  into the middle of a cached page is copy-on-written before the request's
  first cold row lands in it.  All pool arithmetic uses ``available_pages``
  — free heap plus reclaimable cached pages — so a full cache never causes
  a preemption an empty one would not.

Two packings of the same plan
-----------------------------
``schedule()`` emits lane plans the engine runs as a right-aligned
``(lanes, C)`` block — the padded step, kept as the equivalence oracle.
``schedule_ragged()`` packs the SAME policy into one dense token stream
(:class:`RaggedBatch`): ``T = Σ q_len`` token rows, bucketed to a few
widths (powers of two plus their 3/2 midpoints, the ``token_buckets``
knob) so the jitted step stays O(1) compiles.  Because prefill work is
elastic, the packer *trims* prefill chunks (youngest lane first, decode
lanes never) so the live stream lands exactly on a bucket edge whenever
one is reachable — live work fills the padded width instead of dead rows
(``padding_efficiency`` ≈ 1 on mixed steps); only decode-only steps pad
up.  Trimmed tokens are not lost — the lane's cursor simply advances less
this step and the remainder is replanned next step.

The scheduler owns accounting only — queues, tickets, page tables, the
packed numpy arrays; the jax arrays live in
:class:`~repro.serving.core.EngineCore`.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.api import Request, RequestState
from repro.serving.paged import PagedKVCache
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.sampling import InvalidRequest


def default_token_buckets(max_tokens: int) -> Tuple[int, ...]:
    """Bucket widths for the packed stream: {2^k} ∪ {3·2^(k-1)} up to (and
    one past) ``max_tokens`` — 1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, …

    Powers of two alone waste up to half the stream on the round-up; the
    3/2 midpoints cap the pad at ~25% while only doubling the (still O(1))
    trace-bucket count.  The default ``step_tokens = lanes + chunk`` often
    IS a midpoint (e.g. 16 + 32 = 48), so full mixed steps land exactly."""
    ws = {1}
    w = 1
    while w < max_tokens:
        w *= 2
        ws.add(w)
        ws.add(w + w // 2)
    return tuple(sorted(ws))


@dataclasses.dataclass(eq=False)
class RunningRequest:
    """A resident request: its lane, pages, and cursor into known tokens.

    ``eq=False``: queue membership (`remove`, `in`) must be identity —
    field-wise dataclass equality would tuple-compare prompt ndarrays and
    raise on duplicate uids."""
    req: Request
    ticket: int
    pages: List[int] = dataclasses.field(default_factory=list)
    rows: int = 0                     # KV rows already resident
    # memoized admission probe: (cache.version, PrefixHit) — a blocked
    # head-of-queue request is re-considered every schedule, but its match
    # cannot change until the tree does (or its own tokens do: cleared on
    # eviction, where replayed generation grows the known stream)
    probe: Optional[tuple] = None

    def known(self) -> int:
        return len(self.req.prompt) + len(self.req.tokens)

    def remaining(self) -> int:
        return self.known() - self.rows

    def next_tokens(self, n: int):
        """The next ``n`` tokens of the known stream (prompt ⊕ generated)
        starting at the cursor — O(n), without materialising the whole
        stream (a decode lane reads 1 token per step, not O(L))."""
        lp = len(self.req.prompt)
        head = np.asarray(self.req.prompt[self.rows:self.rows + n],
                          np.int32)
        need = n - len(head)
        if need <= 0:
            return head
        off = max(0, self.rows - lp)
        tail = np.asarray(self.req.tokens[off:off + need], np.int32)
        return np.concatenate([head, tail]) if len(head) else tail


@dataclasses.dataclass(frozen=True)
class LanePlan:
    """One lane of one step: stream ``q_len`` tokens of ``run``'s cursor.

    With speculative decoding the streamed chunk may extend past the known
    stream: the last ``len(drafts)`` of the ``q_len`` tokens are *drafted*
    (proposed, unverified — see ``serving/spec.py``); the first
    ``q_len - len(drafts)`` still come off the cursor.  The engine verifies
    every drafted position in the same step and commits only the accepted
    prefix, so the cursor may advance less than ``q_len``.
    """
    run: RunningRequest
    q_len: int
    drafts: Tuple[int, ...] = ()

    @property
    def sample(self) -> bool:
        # The step consumes through the last known token → its final-row
        # logits are the next-token distribution.  Drafted tokens sit past
        # the known stream by construction, so a drafting lane always
        # samples (it is a decode lane whose chunk got extended).
        return (self.run.rows + self.q_len - len(self.drafts)
                == self.run.known())

    def stream_tokens(self) -> np.ndarray:
        """The q_len tokens this lane streams: known-stream chunk ⊕ drafts."""
        base = self.run.next_tokens(self.q_len - len(self.drafts))
        if not self.drafts:
            return base
        return np.concatenate(
            [base, np.asarray(self.drafts, np.int32)])


@dataclasses.dataclass(frozen=True)
class RaggedBatch:
    """One step's plans packed into a dense token stream (see module doc).

    Lane segments abut: stream indices ``cu_seqlens[i] .. cu_seqlens[i+1]``
    belong to ``plans[i]`` (also recorded per token in ``lane_id``).  Rows
    past ``live`` are dead bucket padding: token 0, position 0, lane −1,
    an all-scratch table row — their compute lands on the pool's scratch
    page and is never read back.
    """
    plans: List[LanePlan]
    tokens: np.ndarray        # (width,) int32 packed token stream
    pos: np.ndarray           # (width,) int32 absolute position per token
    lane_id: np.ndarray       # (width,) int32 plan index per token; −1 dead
    table: np.ndarray         # (width, P) int32 per-token page-table rows
    cu_seqlens: np.ndarray    # (len(plans)+1,) int32 lane boundaries
    live: int                 # Σ q_len — real token rows in the stream
    width: int                # bucketed stream width (= tokens.shape[0])


class Scheduler:
    """Continuous batching over a :class:`PagedKVCache` (see module doc)."""

    def __init__(self, kv: PagedKVCache, *, lanes: int = 4,
                 chunk_size: int = 16,
                 step_tokens: Optional[int] = None,
                 token_buckets: Optional[Sequence[int]] = None,
                 prefix_cache: Optional[RadixPrefixCache] = None,
                 spec_k: int = 0, proposer=None, obs=None):
        assert chunk_size >= 1
        self.kv = kv
        self.cache = prefix_cache
        if obs is None:
            from .tracing import ServingObservability
            obs = ServingObservability(enabled=False)
        self.obs = obs
        self.lanes = lanes
        self.chunk_size = chunk_size
        # Speculative decoding (opt-in): with spec_k > 0 and a proposer
        # (see serving/spec.py), decode lanes may stream 1 + d drafted
        # tokens per step, d ≤ spec_k.  Drafts spend only *leftover* step
        # budget and degrade before any resident pays for them.
        self.spec_k = spec_k
        self.proposer = proposer
        self._drafts: Dict[int, Tuple[int, ...]] = {}   # ticket → drafts
        # Fairness knob: max tokens per step across all lanes.  The default
        # admits every decode lane plus one full prefill chunk — prompts
        # stream through spare capacity without monopolising the batch.
        self.step_tokens = step_tokens or (lanes + chunk_size)
        # Ragged-stream width buckets (must cover step_tokens; 1 for the
        # degenerate single-decode step is always included).
        self.token_buckets: Tuple[int, ...] = tuple(sorted(
            set(token_buckets) | {1} if token_buckets
            else default_token_buckets(self.step_tokens)))
        assert self.token_buckets[-1] >= self.step_tokens, (
            f"token_buckets {self.token_buckets} do not cover "
            f"step_tokens={self.step_tokens}")
        self.waiting: List[RunningRequest] = []     # ordered by ticket
        self.running: List[RunningRequest] = []     # ordered by ticket
        # Page-table width high-water mark (see pack()): the table's P axis
        # never shrinks, so the jitted step's trace keys stay O(#buckets)
        # instead of O(#buckets × #table widths).
        self._table_pages = 1
        self._ticket = 0
        self.preempted_count = 0                    # evictions, lifetime
        self._evicted_now: List[int] = []           # within one schedule()
        self.prefix_hit_tokens_step = 0             # granted this schedule()
        self.trimmed_prefill_step = 0               # tokens, this schedule()
        self.trimmed_draft_step = 0                 # tokens, this schedule()

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            # known() == 0 would plan q_len = 0 forever: a lane-wedging
            # livelock, not a servable request.
            raise InvalidRequest("prompt", "empty prompt", uid=req.uid)
        worst = len(req.prompt) + req.max_new
        if self.kv.pages_needed(worst) > self.kv.num_pages:
            raise InvalidRequest(
                "max_new",
                f"needs {self.kv.pages_needed(worst)} pages worst-case "
                f"(> pool of {self.kv.num_pages}) — raise num_pages",
                uid=req.uid)
        req.state = RequestState.WAITING
        self.waiting.append(RunningRequest(req, self._ticket))
        self._ticket += 1
        self.obs.request_submitted(req.uid, prompt_len=len(req.prompt),
                                   max_new=req.max_new)

    def finish(self, run: RunningRequest) -> None:
        """Release a completed request's lane and pages, publishing its full
        prefix pages into the prefix cache first (they stay resident for
        future hits; only its trailing partial page frees outright)."""
        self.running.remove(run)
        self._publish(run)
        self.kv.release(run.pages)
        run.pages = []
        run.req.state = RequestState.FINISHED
        self.obs.request_finished(run.req.uid,
                                  generated=len(run.req.tokens))
        if self.cache is not None:
            self.cache.enforce_budget()

    def abort(self, uid: int) -> bool:
        """Cancel a request by uid → True if it was waiting or running.

        A running request releases exactly like :meth:`finish` — full pages
        published to the prefix cache (refcount-aware release; pages another
        request or the cache still holds are not freed), the lane opens for
        next step's admission — but lands in ``ABORTED``, never in the
        engine's finished list.  A waiting request simply leaves the queue.
        """
        for run in self.waiting:
            if run.req.uid == uid:
                self.waiting.remove(run)
                run.req.done = True
                run.req.state = RequestState.ABORTED
                self.obs.request_finished(uid, aborted=True,
                                          generated=len(run.req.tokens))
                return True
        for run in self.running:
            if run.req.uid == uid:
                self.running.remove(run)
                # A drafting lane's table may still cover the speculative
                # worst case (cursor + 1 + k rows): route the surplus
                # through uncommit FIRST, so the free heap and refcounts
                # match a never-drafted twin and _publish can never see a
                # page past the committed cursor.
                run.pages = self.kv.uncommit(run.pages, run.rows)
                self._publish(run)
                self.kv.release(run.pages)
                run.pages = []
                run.req.done = True
                run.req.state = RequestState.ABORTED
                self.obs.request_finished(uid, aborted=True,
                                          generated=len(run.req.tokens))
                if self.cache is not None:
                    self.cache.enforce_budget()
                return True
        return False

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------- internal
    def _publish(self, run: RunningRequest) -> None:
        """Publish ``run``'s full resident pages into the prefix cache,
        keyed by the tokens whose KV rows they hold.

        Cursor-clamped: only pages whose EVERY row the engine committed
        (``rows // page_size`` of them) are eligible — a mid-prefill abort
        leaves a table covering granted-but-unwritten rows, and publishing
        such a page would serve uncomputed KV to the next hit on the same
        prefix.  (``insert`` also keys by ``rows`` tokens; the explicit
        slice makes the publish safe even if the two ever disagree.)"""
        if self.cache is None or run.rows < self.kv.page_size:
            return
        full = run.rows // self.kv.page_size
        self.cache.insert(run.req.known_tokens()[:run.rows],
                          run.pages[:full])

    def _preempt_youngest(self, older_than: int) -> bool:
        """Evict the youngest resident request with ticket > ``older_than``;
        its cursor rewinds and it re-queues by ticket (recompute preemption).
        Its full pages are published to the prefix cache first — still
        reclaimable under pressure, but if they survive, the victim resumes
        by cache hit instead of recompute — and the release is
        refcount-aware: pages the cache or another request still references
        are never freed by this eviction.  → False when no victim exists."""
        victims = [r for r in self.running if r.ticket > older_than]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.ticket)
        self.running.remove(victim)
        self._publish(victim)
        self.kv.release(victim.pages)
        victim.pages = []
        victim.rows = 0
        victim.probe = None               # known tokens grew: stale match
        victim.req.state = RequestState.PREEMPTED
        self.preempted_count += 1
        self._evicted_now.append(victim.req.uid)
        self.obs.request_preempted(victim.req.uid)
        bisect.insort(self.waiting, victim, key=lambda r: r.ticket)
        if self.cache is not None:
            self.cache.enforce_budget()
        return True

    def _cow_credit(self, page: int) -> bool:
        """True when copy-on-writing ``page`` hands its original straight
        back to the reclaimable pool: the only other holder is the cache,
        so the writer's release leaves it cache-only."""
        return (self.kv.ref[page] == 2 and self.cache is not None
                and self.cache.holds(page))

    def _grant_pages(self, run: RunningRequest, rows_after: int) -> bool:
        """Extend ``run``'s page table to cover ``rows_after`` rows, evicting
        younger residents if the pool runs dry.  Shared pages the coming
        rows would write into (the partial page of a prefix hit) are
        copy-on-written here, before the step runs.  The budget counts each
        copy but *credits* originals whose release returns them to the
        reclaimable pool (cache-only sharers) — without the credit, a
        partial-page hit on a pool the workload physically fits would
        demand a page it is about to give back and wedge the lane forever.
        → False if ``run`` itself lost the fight (only ever happens to
        non-oldest requests)."""
        ps = self.kv.page_size
        lo = run.rows // ps
        hi = min((rows_after - 1) // ps + 1, len(run.pages))
        need = self.kv.pages_needed(rows_after) - len(run.pages)
        while True:
            cow = [i for i in range(lo, hi)
                   if self.kv.ref[run.pages[i]] > 1]
            credit = sum(1 for i in cow if self._cow_credit(run.pages[i]))
            avail = self.kv.available_pages
            # aggregate demand, plus one transient page for the first copy
            if need + len(cow) - credit <= avail and (not cow or avail >= 1):
                break
            if self._preempt_youngest(older_than=run.ticket):
                continue
            # No victims left: before wedging the lane, take sole ownership
            # of a cache-only shared page (leaf eviction, no copy) — the
            # cache yields exactly like it does for any other reclaim.
            if self.cache is not None and any(
                    self.kv.ref[run.pages[i]] == 2
                    and self.cache.release_hold(run.pages[i]) for i in cow):
                continue
            return False                  # run is the youngest: it waits
        # credit-yielding copies first: each returns its original to the
        # reclaimable pool before the next copy draws on it, so the
        # aggregate budget above is also sequentially safe
        for i in sorted(cow, key=lambda i: not self._cow_credit(run.pages[i])):
            old = run.pages[i]
            run.pages[i] = self.kv.cow(old)
            if run.pages[i] != old:
                self.obs.request_cow(run.req.uid)
        for _ in range(need):
            run.pages.append(self.kv.alloc())
        return True

    def _admit(self) -> None:
        while self.waiting and len(self.running) < self.lanes:
            cand = self.waiting[0]
            # Probe the prefix cache with the candidate's known tokens
            # (prompt ⊕ replayed generation): a pure match — nothing is
            # granted until the admission check passes — memoized against
            # the tree version while the head waits on the pool.
            hit = None
            if self.cache is not None:
                if cand.probe is not None and \
                        cand.probe[0] == self.cache.version:
                    hit = cand.probe[1]
                else:
                    hit = self.cache.match(cand.req.known_tokens())
                    cand.probe = (self.cache.version, hit)
            # Admission is against the pool budget for the tokens the
            # request *has* plus one decode row, minus the pages the hit
            # already holds resident.  Granting pins the hit's currently
            # cache-only pages (they stop being reclaimable), so those are
            # subtracted from the available side.
            need = self.kv.pages_needed(cand.known() + 1)
            avail = self.kv.available_pages
            if hit is not None:
                need -= len(hit.pages)
                avail -= sum(1 for p in hit.pages if self.kv.ref[p] == 1)
            if need > avail:
                break                     # FCFS: the head blocks the queue
            self.waiting.pop(0)
            resumed = cand.req.state is RequestState.PREEMPTED
            if hit is not None:
                self.cache.grant(hit, cand.known())
                cand.pages = list(hit.pages)
                cand.rows = hit.tokens
                self.prefix_hit_tokens_step += hit.tokens
            else:
                cand.rows = 0
            cand.req.state = RequestState.PREFILL
            self.obs.request_admitted(
                cand.req.uid,
                hit_tokens=hit.tokens if hit is not None else 0,
                resumed=resumed)
            bisect.insort(self.running, cand, key=lambda r: r.ticket)

    # ---------------------------------------------------------------- plan
    def _plan_wants(self) -> Dict[int, int]:
        """Split the step's token budget: ticket → q_len.  Mandatory work
        first — decode lanes one token each, so prefill bursts never starve
        resident decodes, then prefill chunks oldest first — and only
        *leftover* budget funds speculative drafts (oldest greedy decode
        lane first, up to ``spec_k`` each).  Draft rows are strictly
        opportunistic: a budget-starved step plans exactly what the
        non-speculative scheduler would, it never sheds mandatory tokens
        to keep drafting (the degrade-not-evict fairness rule)."""
        budget = self.step_tokens
        wants: Dict[int, int] = {}
        decodes: List[RunningRequest] = []
        for run in sorted(self.running,
                          key=lambda r: (r.remaining() > 1, r.ticket)):
            q = min(self.chunk_size, run.remaining(), budget)
            if q <= 0:
                continue
            budget -= q
            wants[run.ticket] = q
            if run.remaining() == 1:
                decodes.append(run)
        if self.spec_k > 0 and self.proposer is not None and budget > 0:
            for run in sorted(decodes, key=lambda r: r.ticket):
                if budget <= 0:
                    break
                if run.req.temperature > 0.0:
                    continue    # acceptance rule is argmax equality: greedy
                # d accepted drafts commit d + 1 tokens; never draft past
                # max_new (also keeps rows ≤ prompt + max_new − 1, inside
                # the worst case validated at submit)
                cap = min(self.spec_k, budget,
                          run.req.max_new - len(run.req.tokens) - 1)
                if cap <= 0:
                    continue
                drafts = tuple(
                    int(t) for t in
                    self.proposer(run.req.known_tokens(), cap))[:cap]
                if not drafts:
                    continue
                self._drafts[run.ticket] = drafts
                wants[run.ticket] += len(drafts)
                budget -= len(drafts)
        return wants

    @property
    def drafting(self) -> bool:
        """True while the current schedule carries speculative drafts."""
        return bool(self._drafts)

    def _fits_unforced(self, run: RunningRequest, rows_after: int) -> bool:
        """Would ``_grant_pages(run, rows_after)`` succeed *without* evicting
        anyone?  Same arithmetic as the grant (need + CoW copies − credits
        vs ``available_pages``), minus the preemption loop."""
        ps = self.kv.page_size
        lo = run.rows // ps
        hi = min((rows_after - 1) // ps + 1, len(run.pages))
        need = self.kv.pages_needed(rows_after) - len(run.pages)
        cow = [i for i in range(lo, hi) if self.kv.ref[run.pages[i]] > 1]
        credit = sum(1 for i in cow if self._cow_credit(run.pages[i]))
        avail = self.kv.available_pages
        return need + len(cow) - credit <= avail and (not cow or avail >= 1)

    def _grant_plans(self, wants: Dict[int, int]) -> List[LanePlan]:
        """Grant pages in strict ticket order (seniority decides who may
        evict whom), and only for tokens that actually got budget — a
        budget-starved lane never evicts a resident for rows it will not
        write this step.  A lane that gets no budget or loses its pages
        simply does not appear in the plan.  Speculative draft rows are
        second-class citizens of the pool too: when granting a drafted
        chunk would need a preemption, the drafts shrink (youngest first)
        until the grant fits free — only the mandatory decode token may
        evict a resident, so speculation never costs another request its
        lane."""
        plans: List[LanePlan] = []
        for run in list(sorted(self.running, key=lambda r: r.ticket)):
            if run not in self.running:
                continue                              # evicted by an elder
            q = wants.get(run.ticket)
            if q is None:
                continue
            drafts = orig = self._drafts.get(run.ticket, ())
            while drafts and not self._fits_unforced(run, run.rows + q):
                drafts = drafts[:-1]                  # degrade, don't evict
                q -= 1
            if len(drafts) != len(orig):
                self.trimmed_draft_step += len(orig) - len(drafts)
                if drafts:
                    self._drafts[run.ticket] = drafts
                else:
                    del self._drafts[run.ticket]
            if not self._grant_pages(run, run.rows + q):
                continue
            run.req.state = (RequestState.DECODE if run.remaining() == 1
                             else RequestState.PREFILL)
            plans.append(LanePlan(run, q, tuple(drafts)))
        return plans

    def begin_step(self) -> Dict[int, int]:
        """Admit waiters and split the token budget → ticket → q_len wants.

        The two-phase API lets the engine pick the packing *after* seeing
        the plan: ``begin_step()`` then exactly one of :meth:`plans_for` /
        :meth:`batch_for`.  (The ragged engine used to route full-width
        steps through padded-block plans for their per-chunk page reuse;
        the q-block-tiled varlen kernel made that dispatch unnecessary, so
        only ``mode="padded"`` — the oracle — takes the plans path now.)"""
        self._evicted_now = []
        self.prefix_hit_tokens_step = 0
        self.trimmed_prefill_step = 0
        self.trimmed_draft_step = 0
        self._drafts = {}
        self._admit()
        return self._plan_wants()

    def plans_for(self, wants: Dict[int, int]
                  ) -> Tuple[List[LanePlan], Tuple[int, ...]]:
        """Finish a step as padded-block lane plans → (plans, preempted)."""
        plans = self._grant_plans(wants)
        return plans, tuple(self._evicted_now)

    def schedule(self) -> Tuple[List[LanePlan], Tuple[int, ...]]:
        """→ (lane plans for this step, uids preempted while planning).
        The engine runs these as a right-aligned (lanes, C) block — the
        padded step; :meth:`schedule_ragged` is the packed-stream twin."""
        return self.plans_for(self.begin_step())

    # -------------------------------------------------------- ragged plan
    def _bucket_up(self, t: int) -> int:
        """Smallest bucket width ≥ t (t ≤ step_tokens ≤ buckets[-1])."""
        for w in self.token_buckets:
            if w >= t:
                return w
        return self.token_buckets[-1]

    def _trim_to_bucket(self, wants: Dict[int, int]) -> Dict[int, int]:
        """Trim elastic tokens (never mandatory decodes) so the live stream
        lands on a bucket edge: the padded width is then all live work.
        Speculative drafts are the *most* elastic work in the step — they
        are a bet, not progress — so they go first (youngest lane first),
        then prefill chunk tails (also youngest first, FCFS-consistent).
        Every planned lane keeps ≥ 1 token — a lane trimmed to zero would
        see the identical plan next step and starve for as long as the
        decode lanes keep running (e.g. 8 decode lanes exactly filling a
        bucket plus a 2-token prefill tail).  When the bucket edge is
        unreachable under that progress guarantee — or every bucket ≤ total
        sits below the mandatory-decode floor — pad up instead."""
        total = sum(wants.values())
        if total == 0 or total in self.token_buckets:
            return wants
        runs = {r.ticket: r for r in self.running}
        floor = sum(1 for t in wants
                    if runs[t].remaining() == 1)      # mandatory decode rows
        below = [w for w in self.token_buckets if floor <= w <= total]
        if not below:
            return wants                              # decode-bound: pad up
        cut = total - below[-1]
        trimmable = (sum(len(d) for d in self._drafts.values())
                     + sum(q - 1 for t, q in wants.items()
                           if runs[t].remaining() > 1))
        if cut > trimmable:
            return wants                              # would starve: pad up
        for tkt in sorted(self._drafts, reverse=True):  # drafts: youngest 1st
            if cut == 0:
                break
            if tkt not in wants:
                continue
            take = min(cut, len(self._drafts[tkt]))
            self._drafts[tkt] = self._drafts[tkt][:len(self._drafts[tkt])
                                                  - take]
            if not self._drafts[tkt]:
                del self._drafts[tkt]
            wants[tkt] -= take
            cut -= take
            self.trimmed_draft_step += take
        for tkt in sorted(wants, reverse=True):       # prefill: youngest 1st
            if cut == 0:
                break
            if runs[tkt].remaining() == 1:
                continue
            take = min(cut, wants[tkt] - 1)
            wants[tkt] -= take
            cut -= take
            self.trimmed_prefill_step += take
        return wants

    def pack(self, plans: List[LanePlan]) -> RaggedBatch:
        """Flatten lane plans into one dense bucketed token stream."""
        live = sum(p.q_len for p in plans)
        width = self._bucket_up(max(live, 1))
        pw = max((len(p.run.pages) for p in plans), default=1)
        pw = 1 << max(pw - 1, 0).bit_length()         # table-width bucket
        # High-water mark: without it the table's P axis shrinks whenever
        # the resident mix turns short (fresh arrivals mid-serve), and the
        # jitted step retraces at (stream width × table width) — a compile
        # stall in the middle of live traffic for a shape the engine has
        # already paid for.  Never shrinking costs only masked page blocks
        # the longest-resident request was already scanning.
        self._table_pages = max(self._table_pages, pw)
        pw = self._table_pages
        scratch = self.kv.scratch
        tokens = np.zeros((width,), np.int32)
        pos = np.zeros((width,), np.int32)
        lane_id = np.full((width,), -1, np.int32)
        table = np.full((width, pw), scratch, np.int32)
        cu = np.zeros((len(plans) + 1,), np.int32)
        t = 0
        for i, p in enumerate(plans):
            q = p.q_len
            tokens[t:t + q] = p.stream_tokens()
            pos[t:t + q] = p.run.rows + np.arange(q, dtype=np.int32)
            lane_id[t:t + q] = i
            table[t:t + q, :len(p.run.pages)] = np.asarray(
                p.run.pages, np.int32)[None, :]
            t += q
            cu[i + 1] = t
        return RaggedBatch(plans=plans, tokens=tokens, pos=pos,
                           lane_id=lane_id, table=table, cu_seqlens=cu,
                           live=live, width=width)

    def batch_for(self, wants: Dict[int, int]
                  ) -> Tuple[RaggedBatch, Tuple[int, ...]]:
        """Finish a step as a packed ragged stream → (batch, preempted).
        The wants are trimmed to a bucket edge *before* pages are granted,
        so no resident is ever evicted for rows the trim dropped."""
        plans = self._grant_plans(self._trim_to_bucket(wants))
        return self.pack(plans), tuple(self._evicted_now)

    def schedule_ragged(self) -> Tuple[RaggedBatch, Tuple[int, ...]]:
        """→ (packed token stream for this step, uids preempted planning).
        Same admission / fairness / eviction policy as :meth:`schedule`,
        packed instead of padded."""
        return self.batch_for(self.begin_step())
