"""Continuous-batching scheduler: requests → per-step chunk plans.

One rule unifies every serving phase: a request is a cursor (``rows``) into
its stream of known tokens (prompt ⊕ generated).  Each step the scheduler
grants a resident request the next ``q_len = min(chunk, remaining, budget)``
tokens of that stream; the engine writes their KV rows through the page
table and samples a new token exactly when the cursor reaches the end of
the stream.  Prompt prefill is the cursor sweeping the prompt in fixed-size
chunks; decode is the degenerate chunk of one; resuming a preempted request
is the same sweep over prompt ⊕ already-generated tokens (recompute
preemption — deterministic greedy regenerates the identical suffix).  There
is no separate prefill entry point left to schedule.

Policy
------
- **FCFS admission** against the page-pool budget: the waiting queue is
  ordered by arrival ticket; the head is admitted when a lane is free and
  the pool can hold its *known* tokens (its generation growth is allocated
  lazily, page by page).
- **Token-budget fairness** (``step_tokens``): decode lanes are planned
  first — one token each, so prefill bursts never starve resident decodes —
  then prefill lanes split the remaining budget into chunks, oldest first.
- **Preemption by eviction**: pages are granted in strict ticket order; when
  the pool runs dry the *youngest* resident request is evicted — its pages
  return to the free list, its cursor rewinds to zero, and it re-enters the
  waiting queue (by its original ticket) to be replayed later.  The oldest
  resident request can always evict its way to the whole pool, so progress
  is guaranteed as long as any single request fits (checked at submit).

The scheduler owns accounting only — queues, tickets, page tables; the
jax arrays live in :class:`~repro.serving.core.EngineCore`.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.api import Request, RequestState
from repro.serving.paged import PagedKVCache


@dataclasses.dataclass(eq=False)
class RunningRequest:
    """A resident request: its lane, pages, and cursor into known tokens.

    ``eq=False``: queue membership (`remove`, `in`) must be identity —
    field-wise dataclass equality would tuple-compare prompt ndarrays and
    raise on duplicate uids."""
    req: Request
    ticket: int
    pages: List[int] = dataclasses.field(default_factory=list)
    rows: int = 0                     # KV rows already resident

    def known(self) -> int:
        return len(self.req.prompt) + len(self.req.tokens)

    def remaining(self) -> int:
        return self.known() - self.rows

    def next_tokens(self, n: int):
        """The next ``n`` tokens of the known stream (prompt ⊕ generated)
        starting at the cursor — O(n), without materialising the whole
        stream (a decode lane reads 1 token per step, not O(L))."""
        lp = len(self.req.prompt)
        head = np.asarray(self.req.prompt[self.rows:self.rows + n],
                          np.int32)
        need = n - len(head)
        if need <= 0:
            return head
        off = max(0, self.rows - lp)
        tail = np.asarray(self.req.tokens[off:off + need], np.int32)
        return np.concatenate([head, tail]) if len(head) else tail


@dataclasses.dataclass(frozen=True)
class LanePlan:
    """One lane of one step: stream ``q_len`` tokens of ``run``'s cursor."""
    run: RunningRequest
    q_len: int

    @property
    def sample(self) -> bool:
        # The step consumes through the last known token → its final-row
        # logits are the next-token distribution.
        return self.run.rows + self.q_len == self.run.known()


class Scheduler:
    """Continuous batching over a :class:`PagedKVCache` (see module doc)."""

    def __init__(self, kv: PagedKVCache, *, lanes: int = 4,
                 chunk_size: int = 16,
                 step_tokens: Optional[int] = None):
        assert chunk_size >= 1
        self.kv = kv
        self.lanes = lanes
        self.chunk_size = chunk_size
        # Fairness knob: max tokens per step across all lanes.  The default
        # admits every decode lane plus one full prefill chunk — prompts
        # stream through spare capacity without monopolising the batch.
        self.step_tokens = step_tokens or (lanes + chunk_size)
        self.waiting: List[RunningRequest] = []     # ordered by ticket
        self.running: List[RunningRequest] = []     # ordered by ticket
        self._ticket = 0
        self.preempted_count = 0                    # evictions, lifetime
        self._evicted_now: List[int] = []           # within one schedule()

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            # known() == 0 would plan q_len = 0 forever: a lane-wedging
            # livelock, not a servable request.
            raise ValueError(f"request {req.uid}: empty prompt")
        worst = len(req.prompt) + req.max_new
        if self.kv.pages_needed(worst) > self.kv.num_pages:
            raise ValueError(
                f"request {req.uid} needs {self.kv.pages_needed(worst)} "
                f"pages worst-case (> pool of {self.kv.num_pages}) — raise "
                f"num_pages")
        req.state = RequestState.WAITING
        self.waiting.append(RunningRequest(req, self._ticket))
        self._ticket += 1

    def finish(self, run: RunningRequest) -> None:
        """Release a completed request's lane and pages."""
        self.running.remove(run)
        self.kv.release(run.pages)
        run.pages = []
        run.req.state = RequestState.FINISHED

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------- internal
    def _preempt_youngest(self, older_than: int) -> bool:
        """Evict the youngest resident request with ticket > ``older_than``;
        its cursor rewinds and it re-queues by ticket (recompute preemption).
        → False when no such victim exists."""
        victims = [r for r in self.running if r.ticket > older_than]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.ticket)
        self.running.remove(victim)
        self.kv.release(victim.pages)
        victim.pages = []
        victim.rows = 0
        victim.req.state = RequestState.PREEMPTED
        self.preempted_count += 1
        self._evicted_now.append(victim.req.uid)
        bisect.insort(self.waiting, victim, key=lambda r: r.ticket)
        return True

    def _grant_pages(self, run: RunningRequest, rows_after: int) -> bool:
        """Extend ``run``'s page table to cover ``rows_after`` rows, evicting
        younger residents if the free list runs dry.  → False if ``run``
        itself lost the fight (only ever happens to non-oldest requests)."""
        need = self.kv.pages_needed(rows_after) - len(run.pages)
        while need > self.kv.free_pages:
            if not self._preempt_youngest(older_than=run.ticket):
                return False              # run is the youngest: it waits
        for _ in range(need):
            run.pages.append(self.kv.alloc())
        return True

    def _admit(self) -> None:
        while self.waiting and len(self.running) < self.lanes:
            cand = self.waiting[0]
            # Admission is against the pool budget for the tokens the
            # request *has* (prompt ⊕ replayed generation) plus one decode
            # row; further growth allocates lazily and may preempt.
            if self.kv.pages_needed(cand.known() + 1) > self.kv.free_pages:
                break                     # FCFS: the head blocks the queue
            self.waiting.pop(0)
            cand.rows = 0
            cand.req.state = RequestState.PREFILL
            bisect.insort(self.running, cand, key=lambda r: r.ticket)

    # ---------------------------------------------------------------- plan
    def schedule(self) -> Tuple[List[LanePlan], Tuple[int, ...]]:
        """→ (lane plans for this step, uids preempted while planning).

        The token budget is spent decode-lanes-first (fairness); pages are
        then granted in strict ticket order (who may evict whom is
        seniority), and only for tokens that actually got budget — a
        budget-starved lane never evicts a resident for rows it will not
        write this step.  A lane that gets no budget or loses its pages
        simply does not appear in the plan.
        """
        self._evicted_now = []
        self._admit()
        budget = self.step_tokens
        wants = {}                                    # ticket → q_len
        for run in sorted(self.running,
                          key=lambda r: (r.remaining() > 1, r.ticket)):
            q = min(self.chunk_size, run.remaining(), budget)
            if q <= 0:
                continue
            budget -= q
            wants[run.ticket] = q
        plans: List[LanePlan] = []
        for run in list(sorted(self.running, key=lambda r: r.ticket)):
            if run not in self.running:
                continue                              # evicted by an elder
            q = wants.get(run.ticket)
            if q is None or not self._grant_pages(run, run.rows + q):
                continue
            run.req.state = (RequestState.DECODE if run.remaining() == 1
                             else RequestState.PREFILL)
            plans.append(LanePlan(run, q))
        return plans, tuple(self._evicted_now)
