"""Paged KV cache: fixed-size pages, free-list allocation, per-slot tables.

The slot-contiguous engine reserves ``max_len`` cache rows per slot up front,
so one long-context slot dictates the memory bill of every short request —
the serving-side analogue of the O(l²) logit matrix HASTILY streams away.
Here the resident KV store is a *pool* of fixed-size pages; each sequence
owns just the pages its current length needs (a page table per slot) and
decode attends over each lane's live rows *in place* through the table
(``kernels/paged_attention``).  Linear-in-live-tokens memory is the paper's
O(l) pipelining restated for the cache.

Mechanics
---------
- The pool is ``model.init_cache(num_pages + 1, page_size)``: every cache
  leaf keeps its family layout, with the batch dim reinterpreted as the page
  id and the length dim as the in-page offset.  Page ``num_pages`` is a
  scratch page — writes from inactive batch lanes land there.
- A free list (a min-heap: pages are handed out lowest-id-first, so reuse is
  deterministic and allocations cluster at the bottom of the pool) hands out
  physical pages; admission *reserves* the worst-case page count
  (ceil((prompt+max_new)/page_size)) so lazy per-token allocation can never
  deadlock mid-decode, while physical pages are only taken as the sequence
  actually grows.
- Decode never touches this module: the engine hands ``(pool, page_table,
  positions)`` straight to the model's paged decode step, which reads pages
  in place (``kernels/paged_attention``) and writes the one new KV row at
  its (physical page, offset).  ``gather`` — the materialised contiguous
  view (B, …, P·page_size, …) — survives only as the oracle for
  cross-checking the in-place path against the naive backends.

Only cache layouts whose every leaf grows with ``max_len`` are supported
(standard bf16/f32 and INT8-quantised KV caches).  SSM states are O(1) per
slot (nothing to page) and ring-buffer sliding-window caches are already
O(window); both are rejected at construction with a clear error.
"""
from __future__ import annotations

import heapq
from typing import Any, List

import jax
import jax.numpy as jnp

Pytree = Any


def cache_batch_axes(tree: Pytree) -> Pytree:
    """Per-leaf batch axis of a model cache pytree.

    Scan-stacked (``periods``) cache leaves carry the period dim first, so
    their batch axis is 1; everything else is 0.  Shared by both serving
    engines and the page pool (where "batch" is the page id).
    """
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: 1 if any(str(getattr(k, "key", "")) == "periods"
                               for k in kp) else 0,
        tree)


class PagedKVCache:
    """Page pool + free list over a model's cache pytree (see module doc)."""

    def __init__(self, model, num_pages: int, page_size: int):
        self.model = model
        self.num_pages = num_pages
        self.page_size = page_size
        self.scratch = num_pages                    # sink page for idle lanes
        self.pool = model.init_cache(num_pages + 1, page_size)
        self.axes = cache_batch_axes(self.pool)   # page id plays batch here
        # Length axis per leaf, discovered by growing max_len: paging is only
        # sound if every leaf scales with it (k/v rows, quant scales, …).
        small = jax.eval_shape(lambda: model.init_cache(1, page_size))
        big = jax.eval_shape(lambda: model.init_cache(1, 2 * page_size))
        if (jax.tree_util.tree_structure(small)
                != jax.tree_util.tree_structure(big)):
            raise ValueError(
                f"paged KV cache: {model.cfg.name} cache *structure* changes "
                f"with max_len (e.g. ring-buffer local windows appearing "
                f"around page_size={page_size}) — serve this config with the "
                f"slot-contiguous engine")
        def length_axis(kp, a, b, ax):
            diff = [i for i, (da, db) in enumerate(zip(a.shape, b.shape))
                    if da != db]
            if diff != [ax + 2] or b.shape[ax + 2] != 2 * a.shape[ax + 2]:
                path = jax.tree_util.keystr(kp)
                raise ValueError(
                    f"paged KV cache: leaf {path} (shape {a.shape}) does not "
                    f"scale with max_len on axis {ax + 2} — SSM states and "
                    f"ring-buffer sliding-window caches are not pageable; "
                    f"serve this config with the slot-contiguous engine")
            return ax + 2
        self.laxes = jax.tree_util.tree_map_with_path(
            length_axis, small, big, self.axes)
        self.free: List[int] = list(range(num_pages))   # min-heap by page id
        self.reserved = 0

        def write(pool, caches1, ids):
            n, ps = ids.shape[0], self.page_size

            def wr(pl, one, ax, lax):
                s = one.shape
                assert s[ax] == 1 and s[lax] == n * ps, (s, ax, lax)
                one = one.reshape(s[:lax] + (n, ps) + s[lax + 1:])
                one = jnp.squeeze(one, ax)          # page axis now at lax-1
                one = jnp.moveaxis(one, lax - 1, ax)
                return pl.at[(slice(None),) * ax + (ids,)].set(
                    one.astype(pl.dtype))

            return jax.tree.map(wr, pool, caches1, self.axes, self.laxes)

        # donated pool: admission writes n0 pages in place instead of eagerly
        # copying the whole pool once per cache leaf (retraces per page count,
        # like the per-length prefill buckets).
        self._write = jax.jit(write, donate_argnums=(0,))

    # ------------------------------------------------------------ free list
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_reserve(self, n: int) -> bool:
        return self.reserved + n <= self.num_pages

    def reserve(self, n: int) -> None:
        assert self.can_reserve(n), (n, self.reserved, self.num_pages)
        self.reserved += n

    def alloc(self) -> int:
        # Reservations guarantee this pop never fails mid-decode.  Lowest
        # id first (not LIFO): page ids stay dense at the bottom of the
        # pool for locality, and allocation order is deterministic under
        # any release order — tests can predict physical layout.
        return heapq.heappop(self.free)

    def release(self, pages: List[int], reserved: int) -> None:
        for p in pages:
            heapq.heappush(self.free, p)
        self.reserved -= reserved

    # ------------------------------------------------------------- pool ops
    def write_prefill(self, caches1: Pytree, pages: List[int]) -> None:
        """Scatter a b=1 contiguous prefill cache (length n·ps) into pages."""
        self.pool = self._write(self.pool, caches1,
                                jnp.asarray(pages, jnp.int32))

    def gather(self, pool: Pytree, tbl: jax.Array) -> Pytree:
        """Page tables (B, P) → contiguous view caches (B, …, P·ps, …).

        This is the O(B·H·L·D) copy the in-place decode path deleted; it
        remains only as the oracle for cross-checking ``paged_attention``
        against the contiguous backends (tests, benchmarks).  Nothing on
        the decode hot path calls it.
        """
        def g(leaf, ax, lax):
            out = jnp.take(leaf, tbl, axis=ax)      # B,P inserted at ax
            out = jnp.moveaxis(out, ax + 1, lax)    # P next to in-page offset
            s = out.shape
            return out.reshape(s[:lax] + (s[lax] * s[lax + 1],) + s[lax + 2:])
        return jax.tree.map(g, pool, self.axes, self.laxes)
