"""Paged KV cache: fixed-size pages, free-list allocation, per-slot tables.

The slot-contiguous engine reserves ``max_len`` cache rows per slot up front,
so one long-context slot dictates the memory bill of every short request —
the serving-side analogue of the O(l²) logit matrix HASTILY streams away.
Here the resident KV store is a *pool* of fixed-size pages; each sequence
owns just the pages its current length needs (a page table per request) and
every phase — chunked prefill and decode alike — writes its KV rows *in
place* through the table and attends the same way
(``kernels/paged_attention``).  Linear-in-live-tokens memory is the paper's
O(l) pipelining restated for the cache.

Mechanics
---------
- The pool is ``model.init_cache(num_pages + 1, page_size)``: every cache
  leaf keeps its family layout, with the batch dim reinterpreted as the page
  id and the length dim as the in-page offset.  Page ``num_pages`` is a
  scratch page — writes from idle lanes and right-align padding rows land
  there (and are masked by ``kv_len`` on every read).
- A free list (a min-heap: pages are handed out lowest-id-first, so reuse is
  deterministic and allocations cluster at the bottom of the pool) hands out
  physical pages.  Allocation is lazy — a page is taken only as a sequence's
  rows actually reach it — and the scheduler preempts-by-eviction when the
  pool runs dry, so there is no up-front worst-case reservation.
- This module never touches jax compute: the engine hands ``(pool,
  page_table, kv_len, q_len)`` straight to the model's unified paged step,
  which reads pages in place and writes each live row at its (physical
  page, offset).  ``gather`` — the materialised contiguous
  (B, …, P·page_size, …) view — survives only as the oracle for
  cross-checking the in-place path against the naive backends.  (The old
  ``write_prefill`` contiguous-then-scatter copy is gone: chunked prefill
  writes pages directly.)

Only cache layouts whose every leaf grows with ``max_len`` are pageable
(standard bf16/f32 and INT8-quantised KV caches).  SSM states are O(1) per
slot (nothing to page) and ring-buffer sliding-window caches are already
O(window); both raise :class:`~repro.serving.api.UnsupportedCacheLayout`
at construction.
"""
from __future__ import annotations

import heapq
from typing import Any, List

import jax
import jax.numpy as jnp

from repro.serving.api import UnsupportedCacheLayout

Pytree = Any


def cache_batch_axes(tree: Pytree) -> Pytree:
    """Per-leaf batch axis of a model cache pytree.

    Scan-stacked (``periods``) cache leaves carry the period dim first, so
    their batch axis is 1; everything else is 0.  Shared by both serving
    engines and the page pool (where "batch" is the page id).
    """
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: 1 if any(str(getattr(k, "key", "")) == "periods"
                               for k in kp) else 0,
        tree)


def _check_pageable(model, page_size: int) -> Pytree:
    """Validate that every cache leaf scales with ``max_len``; → length axes.

    The pool only ever builds caches at ``max_len = page_size``, so the
    doubling probe that discovers each leaf's length axis must stay *at or
    below* page_size (``page_size/2`` vs ``page_size`` for even pages) —
    probing past it would materialise ring buffers the pool will never see
    and falsely reject ``window == page_size`` configs.  The supported
    boundary is ``window >= page_size``.

    Classifies the failure so serving errors name the layout, not a shape:
    a ``pos`` leaf anywhere (or a structure that changes as ``max_len``
    approaches ``page_size``) is a ring-buffer sliding-window cache; a
    leaf with no length axis at all is SSM state.
    """
    name = model.cfg.name
    axes_of = cache_batch_axes
    if page_size % 2 == 0 and page_size >= 2:
        lens = (page_size // 2, page_size)
    else:                       # odd pages: over-strict probe past the pool
        lens = (page_size, 2 * page_size)
    small = jax.eval_shape(lambda: model.init_cache(1, lens[0]))
    big = jax.eval_shape(lambda: model.init_cache(1, lens[1]))

    ring = [jax.tree_util.keystr(kp)
            for kp, _ in jax.tree_util.tree_leaves_with_path(big)
            if any(str(getattr(k, "key", "")) == "pos" for k in kp)]
    if ring:
        raise UnsupportedCacheLayout(
            "ring_buffer_sliding_window", name,
            f"leaf {ring[0]} carries ring-buffer slot positions "
            f"(window narrower than page_size={page_size})")
    if (jax.tree_util.tree_structure(small)
            != jax.tree_util.tree_structure(big)):
        raise UnsupportedCacheLayout(
            "ring_buffer_sliding_window", name,
            f"cache *structure* changes with max_len (ring-buffer local "
            f"windows appearing at or below page_size={page_size})")

    def length_axis(kp, a, b, ax):
        diff = [i for i, (da, db) in enumerate(zip(a.shape, b.shape))
                if da != db]
        path = jax.tree_util.keystr(kp)
        if not diff:
            raise UnsupportedCacheLayout(
                "ssm_state", name,
                f"leaf {path} (shape {a.shape}) is O(1) per slot — no "
                f"length axis to page")
        if diff != [ax + 2] or b.shape[ax + 2] != 2 * a.shape[ax + 2]:
            raise UnsupportedCacheLayout(
                "non_length_scaling", name,
                f"leaf {path} (shape {a.shape}) does not scale with "
                f"max_len on axis {ax + 2}")
        return ax + 2

    return jax.tree_util.tree_map_with_path(
        length_axis, small, big, axes_of(small))


class PagedKVCache:
    """Page pool + free list over a model's cache pytree (see module doc)."""

    def __init__(self, model, num_pages: int, page_size: int):
        self.model = model
        self.num_pages = num_pages
        self.page_size = page_size
        self.scratch = num_pages                    # sink page for idle rows
        # Length axis per leaf, discovered by growing max_len: paging is only
        # sound if every leaf scales with it (k/v rows, quant scales, …).
        # Raises UnsupportedCacheLayout (with the layout name) otherwise.
        self.laxes = _check_pageable(model, page_size)
        self.pool = model.init_cache(num_pages + 1, page_size)
        self.axes = cache_batch_axes(self.pool)   # page id plays batch here
        self.free: List[int] = list(range(num_pages))   # min-heap by page id

    # ------------------------------------------------------------ free list
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self.free)

    def alloc(self) -> int:
        # Lowest id first (not LIFO): page ids stay dense at the bottom of
        # the pool for locality, and allocation order is deterministic under
        # any release order — tests can predict physical layout.  The
        # scheduler checks ``free_pages`` (and preempts) before popping.
        return heapq.heappop(self.free)

    def release(self, pages: List[int]) -> None:
        for p in pages:
            heapq.heappush(self.free, p)

    # ------------------------------------------------------------- pool ops
    def gather(self, pool: Pytree, tbl: jax.Array) -> Pytree:
        """Page tables (B, P) → contiguous view caches (B, …, P·ps, …).

        This is the O(B·H·L·D) copy the in-place paths deleted; it remains
        only as the oracle for cross-checking ``paged_attention`` against
        the contiguous backends (tests, benchmarks).  Nothing on the serving
        hot path — prefill or decode — calls it.
        """
        def g(leaf, ax, lax):
            out = jnp.take(leaf, tbl, axis=ax)      # B,P inserted at ax
            out = jnp.moveaxis(out, ax + 1, lax)    # P next to in-page offset
            s = out.shape
            return out.reshape(s[:lax] + (s[lax] * s[lax + 1],) + s[lax + 2:])
        return jax.tree.map(g, pool, self.axes, self.laxes)
