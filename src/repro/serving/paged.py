"""Paged KV cache: fixed-size pages, free-list allocation, per-slot tables.

The slot-contiguous engine reserves ``max_len`` cache rows per slot up front,
so one long-context slot dictates the memory bill of every short request —
the serving-side analogue of the O(l²) logit matrix HASTILY streams away.
Here the resident KV store is a *pool* of fixed-size pages; each sequence
owns just the pages its current length needs (a page table per request) and
every phase — chunked prefill and decode alike — writes its KV rows *in
place* through the table and attends the same way
(``kernels/paged_attention``).  Linear-in-live-tokens memory is the paper's
O(l) pipelining restated for the cache.

Mechanics
---------
- The pool is ``model.init_cache(num_pages + 1, page_size)``: every cache
  leaf keeps its family layout, with the batch dim reinterpreted as the page
  id and the length dim as the in-page offset.  Page ``num_pages`` is a
  scratch page — writes from idle lanes and right-align padding rows land
  there (and are masked by ``kv_len`` on every read).
- A free list (a min-heap: pages are handed out lowest-id-first, so reuse is
  deterministic and allocations cluster at the bottom of the pool) hands out
  physical pages.  Allocation is lazy — a page is taken only as a sequence's
  rows actually reach it — and the scheduler preempts-by-eviction when the
  pool runs dry, so there is no up-front worst-case reservation.
- Every physical page carries a **refcount**: one per page-table that names
  it, plus one when the prefix cache (``serving/prefix_cache.py``) holds it.
  ``alloc`` hands out exclusive pages (ref 1), ``share`` adds a reference
  (a prefix-cache hit granting resident pages to a new request), and
  ``release`` only returns a page to the free heap when its last reference
  drops — evicting one request can never free another request's shared
  prefix, and the free heap never contains a referenced page.  Writing into
  a *shared* page goes through ``cow``: the writer gets a fresh copy
  (copy-on-write) and drops its reference on the original, so the cached
  prefix stays immutable.  When the heap runs dry, ``alloc`` reclaims
  least-recently-used *unreferenced* cached pages through the attached
  prefix cache before the scheduler ever has to preempt a live request.
- This module never touches jax compute: the engine hands ``(pool,
  page_table, kv_len, q_len)`` straight to the model's unified paged step,
  which reads pages in place and writes each live row at its (physical
  page, offset).  ``gather`` — the materialised contiguous
  (B, …, P·page_size, …) view — survives only as the oracle for
  cross-checking the in-place path against the naive backends.  (The old
  ``write_prefill`` contiguous-then-scatter copy is gone: chunked prefill
  writes pages directly.)

Only cache layouts whose every leaf grows with ``max_len`` are pageable
(standard bf16/f32 and INT8-quantised KV caches).  SSM states are O(1) per
slot (nothing to page) and ring-buffer sliding-window caches are already
O(window); both raise :class:`~repro.serving.api.UnsupportedCacheLayout`
at construction.
"""
from __future__ import annotations

import heapq
from typing import Any, List

import jax
import jax.numpy as jnp

from repro.serving.api import UnsupportedCacheLayout

Pytree = Any


def cache_batch_axes(tree: Pytree) -> Pytree:
    """Per-leaf batch axis of a model cache pytree.

    Scan-stacked (``periods``) cache leaves carry the period dim first, so
    their batch axis is 1; everything else is 0.  Shared by both serving
    engines and the page pool (where "batch" is the page id).
    """
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: 1 if any(str(getattr(k, "key", "")) == "periods"
                               for k in kp) else 0,
        tree)


def _check_pageable(model, page_size: int) -> Pytree:
    """Validate that every cache leaf scales with ``max_len``; → length axes.

    The pool only ever builds caches at ``max_len = page_size``, so the
    doubling probe that discovers each leaf's length axis must stay *at or
    below* page_size (``page_size/2`` vs ``page_size`` for even pages) —
    probing past it would materialise ring buffers the pool will never see
    and falsely reject ``window == page_size`` configs.  The supported
    boundary is ``window >= page_size``.

    Classifies the failure so serving errors name the layout, not a shape:
    a ``pos`` leaf anywhere (or a structure that changes as ``max_len``
    approaches ``page_size``) is a ring-buffer sliding-window cache; a
    leaf with no length axis at all is SSM state.
    """
    name = model.cfg.name
    axes_of = cache_batch_axes
    if page_size % 2 == 0 and page_size >= 2:
        lens = (page_size // 2, page_size)
    else:                       # odd pages: over-strict probe past the pool
        lens = (page_size, 2 * page_size)
    small = jax.eval_shape(lambda: model.init_cache(1, lens[0]))
    big = jax.eval_shape(lambda: model.init_cache(1, lens[1]))

    ring = [jax.tree_util.keystr(kp)
            for kp, _ in jax.tree_util.tree_leaves_with_path(big)
            if any(str(getattr(k, "key", "")) == "pos" for k in kp)]
    if ring:
        raise UnsupportedCacheLayout(
            "ring_buffer_sliding_window", name,
            f"leaf {ring[0]} carries ring-buffer slot positions "
            f"(window narrower than page_size={page_size})")
    if (jax.tree_util.tree_structure(small)
            != jax.tree_util.tree_structure(big)):
        raise UnsupportedCacheLayout(
            "ring_buffer_sliding_window", name,
            f"cache *structure* changes with max_len (ring-buffer local "
            f"windows appearing at or below page_size={page_size})")

    def length_axis(kp, a, b, ax):
        diff = [i for i, (da, db) in enumerate(zip(a.shape, b.shape))
                if da != db]
        path = jax.tree_util.keystr(kp)
        if not diff:
            raise UnsupportedCacheLayout(
                "ssm_state", name,
                f"leaf {path} (shape {a.shape}) is O(1) per slot — no "
                f"length axis to page")
        if diff != [ax + 2] or b.shape[ax + 2] != 2 * a.shape[ax + 2]:
            raise UnsupportedCacheLayout(
                "non_length_scaling", name,
                f"leaf {path} (shape {a.shape}) does not scale with "
                f"max_len on axis {ax + 2}")
        return ax + 2

    return jax.tree_util.tree_map_with_path(
        length_axis, small, big, axes_of(small))


class PagedKVCache:
    """Page pool + free list over a model's cache pytree (see module doc)."""

    def __init__(self, model, num_pages: int, page_size: int, *, obs=None):
        self.model = model
        self.num_pages = num_pages
        self.page_size = page_size
        self.obs = obs                              # ServingObservability

        self.scratch = num_pages                    # sink page for idle rows
        # Length axis per leaf, discovered by growing max_len: paging is only
        # sound if every leaf scales with it (k/v rows, quant scales, …).
        # Raises UnsupportedCacheLayout (with the layout name) otherwise.
        self.laxes = _check_pageable(model, page_size)
        self.pool = model.init_cache(num_pages + 1, page_size)
        self.axes = cache_batch_axes(self.pool)   # page id plays batch here
        self.free: List[int] = list(range(num_pages))   # min-heap by page id
        # References per physical page: one per page-table naming it, plus
        # one while the prefix cache holds it.  The scratch page is outside
        # the refcount world entirely (never allocated, shared or freed).
        self.ref: List[int] = [0] * num_pages
        self.cow_copies = 0                         # lifetime CoW page copies
        self._cache = None                          # RadixPrefixCache, if any
        self._copy_fn = None                        # lazy jitted page copy

    # ------------------------------------------------------------ free list
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def available_pages(self) -> int:
        """Pages an ``alloc`` can obtain without preempting anyone: the free
        heap plus cached pages no request references (reclaimed LRU-first
        through the attached prefix cache)."""
        extra = self._cache.reclaimable_pages if self._cache is not None else 0
        return len(self.free) + extra

    def attach_cache(self, cache) -> None:
        """Wire a prefix cache in as the reclaim source for ``alloc``."""
        self._cache = cache

    def alloc(self) -> int:
        # Lowest id first (not LIFO): page ids stay dense at the bottom of
        # the pool for locality, and allocation order is deterministic under
        # any release order — tests can predict physical layout.  The
        # scheduler checks ``available_pages`` (and preempts) before popping;
        # when the heap itself is dry, unreferenced cached prefix pages are
        # reclaimed LRU-first to refill it.
        while not self.free:
            if self._cache is None or not self._cache.evict_one():
                raise RuntimeError(
                    "page pool exhausted: no free or reclaimable pages "
                    "(scheduler must check available_pages before alloc)")
        p = heapq.heappop(self.free)
        self.ref[p] = 1
        return p

    def share(self, page: int) -> None:
        """Add a reference to a resident page (cache hold / cache-hit grant).
        Only live pages can be shared — a page on the free heap has no
        content to share."""
        if self.ref[page] <= 0:
            raise ValueError(f"share of unreferenced page {page}")
        self.ref[page] += 1

    def release_one(self, page: int) -> None:
        """Drop one reference; the page returns to the free heap only when
        the *last* reference drops — a shared prefix survives any one
        holder's eviction, and the heap never sees a referenced page."""
        if self.ref[page] <= 0:
            raise ValueError(f"double release of page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            heapq.heappush(self.free, page)

    def release(self, pages: List[int]) -> None:
        for p in pages:
            self.release_one(p)

    def uncommit(self, pages: List[int], rows: int) -> List[int]:
        """Shrink a page table to what ``rows`` committed rows need,
        releasing the surplus tail pages — the rollback half of speculative
        decoding.  The engine grants pages for the *drafted* worst case
        (cursor + 1 + k rows), the verify step writes KV rows into them,
        and commit keeps only the accepted prefix; any page holding nothing
        but rejected rows comes back here.  Rejected rows need no content
        rollback: a row past the cursor is dead — every read is masked by
        the reader's own ``kv_len``/``q_pos`` bound, and the row is
        rewritten before the cursor ever crosses it again.  Only the page
        *accounting* must rewind, and since draft pages were freshly
        allocated this step (drafts extend the table's tail; shared prefix
        pages are never past the cursor), releasing them restores the free
        heap and refcounts exactly as if the drafts were never granted.
        Returns the trimmed table (a new list)."""
        keep = self.pages_needed(rows)
        assert keep <= len(pages), (
            f"uncommit: {rows} rows need {keep} pages but table has "
            f"{len(pages)}")
        surplus = pages[keep:]
        self.release(surplus)
        return pages[:keep]

    def cow(self, page: int) -> int:
        """Copy-on-write: make ``page`` writable for one holder.

        Exclusive pages (ref 1) are returned as-is — writing in place is
        safe.  Shared pages are copied leaf-by-leaf into a freshly allocated
        page (the caller must have checked ``available_pages``); the
        caller's reference moves to the copy and the original — typically a
        prefix-cache page whose tail rows a new request is about to
        overwrite — stays immutable for its other holders.
        """
        if self.ref[page] <= 1:
            return page
        fresh = self.alloc()
        if self._copy_fn is None:
            def copy_page(pool, src, dst):
                def one(leaf, ax):
                    idx = (slice(None),) * ax
                    return leaf.at[idx + (dst,)].set(leaf[idx + (src,)])
                return jax.tree.map(one, pool, self.axes)
            self._copy_fn = jax.jit(copy_page, donate_argnums=(0,))
        self.pool = self._copy_fn(self.pool, jnp.int32(page), jnp.int32(fresh))
        self.release_one(page)
        self.cow_copies += 1
        if self.obs is not None:
            self.obs.cow_copy()
        return fresh

    # ------------------------------------------------------------- pool ops
    def gather(self, pool: Pytree, tbl: jax.Array) -> Pytree:
        """Page tables (B, P) → contiguous view caches (B, …, P·ps, …).

        This is the O(B·H·L·D) copy the in-place paths deleted; it remains
        only as the oracle for cross-checking ``paged_attention`` against
        the contiguous backends (tests, benchmarks).  Nothing on the serving
        hot path — prefill or decode — calls it.
        """
        def g(leaf, ax, lax):
            out = jnp.take(leaf, tbl, axis=ax)      # B,P inserted at ax
            out = jnp.moveaxis(out, ax + 1, lax)    # P next to in-page offset
            s = out.shape
            return out.reshape(s[:lax] + (s[lax] * s[lax + 1],) + s[lax + 2:])
        return jax.tree.map(g, pool, self.axes, self.laxes)
