"""Shared-prefix KV reuse: a radix cache over page-aligned token blocks.

A production request stream is massively redundant at its head: millions of
requests open with the same system prompt / few-shot template, and the paper's
central memory argument — KV capacity, not FLOPs, bounds what the hardware
can hold concurrently — makes recomputing *and re-storing* that identical
prefix per request the single most wasteful thing a serving stack can do.
Because a request is already "a cursor into prompt ⊕ generated" and chunked
prefill can start at any offset (PR 3), reuse drops in without touching the
step math: grant the new request the *resident* pages of its cached prefix,
start its cursor at the first cold token, and the engine's existing chunk
step does the rest.

Structure
---------
The cache is a radix tree whose edges are **page-aligned token-ID blocks**:
a node at depth ``d`` is reached by the exact token blocks
``tokens[0:ps], …, tokens[(d-1)·ps:d·ps]`` and owns the one physical page
holding those ``ps`` KV rows *given that prefix path*.  KV content depends
only on the token prefix (deterministic model), so a path is a complete
content address — two requests reaching the same node may share its page
bit-for-bit.

- ``match(tokens)`` walks full blocks, then extends into the next block by
  longest-common-prefix: a **partial-page hit** grants the deepest page too,
  with only its first ``lcp`` rows valid.  Matching is capped at
  ``len(tokens) − 1``: at least one known token is always left for the
  engine to stream, because sampling happens when the cursor consumes the
  final known token — a 100%-cached prompt still runs a width-1 step.
- ``grant(hit)`` takes one pool reference per granted page
  (:meth:`PagedKVCache.share`) and stamps the path's LRU clock.  A granted
  *full* page is never written again (new rows land past it); a granted
  *partial* page is copy-on-written by the scheduler the moment the request
  writes its first cold row into it, so the cached original stays immutable.
- ``insert(tokens, pages)`` publishes a finished (or evicted) request's
  **full** pages back into the tree — the trailing partially-filled page is
  never cached.  First publisher wins on path collisions; duplicate pages
  from concurrent cold runs simply fall back to the free heap when their
  request releases them.

Eviction
--------
Cached pages whose only reference is the cache itself are *reclaimable*:
still resident, but the pool may take them back.  ``evict_one`` removes the
least-recently-used reclaimable **leaf** (leaf-first, so the tree never
strands unreachable descendants whose path broke), releases its page to the
free heap, and exposes its parent as the next candidate.  Because a hit
grants its whole path, request-referenced nodes are closed under ancestors —
so every node whose page has refcount 1 is reclaimable leaf-first, and
``reclaimable_pages`` is an exact count, not an estimate.  ``max_pages``
optionally caps the cache's resident footprint; the pool's ``alloc`` also
reclaims on demand when its free heap runs dry, so cached pages never cost a
live request its residency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.paged import PagedKVCache

Block = Tuple[int, ...]


class _Node:
    """One cached page: reached by its block path, LRU-stamped on use."""
    __slots__ = ("block", "page", "parent", "children", "stamp")

    def __init__(self, block: Block, page: int, parent: "_Node", stamp: int):
        self.block = block
        self.page = page
        self.parent = parent
        self.children: Dict[Block, "_Node"] = {}
        self.stamp = stamp


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """Result of probing the cache with a request's known tokens.

    ``pages`` are root-ward resident pages covering ``tokens`` KV rows; when
    ``partial_rows > 0`` the last page is only valid through that many rows
    (the scheduler CoWs it before the first write past them).  Granting is a
    separate step (:meth:`RadixPrefixCache.grant`) so a probe that loses the
    admission check mutates nothing."""
    pages: Tuple[int, ...]
    tokens: int
    partial_rows: int
    nodes: Tuple[_Node, ...] = dataclasses.field(repr=False, default=())


class RadixPrefixCache:
    """Radix tree of page-aligned token blocks → resident pool pages."""

    def __init__(self, kv: PagedKVCache,
                 max_pages: Optional[int] = None, *, obs=None):
        if max_pages is not None and max_pages < 1:
            raise ValueError(f"max_pages must be >= 1, got {max_pages}")
        self.kv = kv
        self.obs = obs                          # ServingObservability
        self.page_size = kv.page_size
        self.max_pages = max_pages
        self.root = _Node((), -1, None, 0)      # type: ignore[arg-type]
        self._nodes: Dict[int, _Node] = {}      # page id → node
        self._clock = itertools.count(1)        # deterministic LRU time
        # telemetry (lifetime; the bench diffs around phases)
        self.lookups = 0
        self.lookup_tokens = 0
        self.hits = 0
        self.hit_tokens = 0
        self.partial_hits = 0
        self.shared_page_grants = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        # bumped on any tree mutation (insert/evict) so a blocked
        # head-of-queue request's probe can be memoized, not re-walked
        # every schedule while nothing changed
        self.version = 0
        kv.attach_cache(self)

    # ------------------------------------------------------------- metrics
    @property
    def cached_pages(self) -> int:
        return len(self._nodes)

    @property
    def reclaimable_pages(self) -> int:
        """Cached pages only the cache references.  Request grants cover
        whole root-ward paths, so these are exactly the pages evictable
        leaf-first without touching a live request."""
        return sum(1 for n in self._nodes.values()
                   if self.kv.ref[n.page] == 1)

    def holds(self, page: int) -> bool:
        """True while ``page`` backs a tree node (i.e. carries a cache ref)."""
        return page in self._nodes

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted known tokens served from resident pages."""
        return self.hit_tokens / max(self.lookup_tokens, 1)

    def stats(self) -> Dict[str, float]:
        return {"lookups": self.lookups, "lookup_tokens": self.lookup_tokens,
                "hits": self.hits, "hit_tokens": self.hit_tokens,
                "hit_rate": self.hit_rate, "partial_hits": self.partial_hits,
                "shared_page_grants": self.shared_page_grants,
                "inserted_pages": self.inserted_pages,
                "evicted_pages": self.evicted_pages,
                "cached_pages": self.cached_pages,
                "reclaimable_pages": self.reclaimable_pages,
                "cow_copies": self.kv.cow_copies}

    # -------------------------------------------------------------- lookup
    def match(self, tokens: Sequence[int]) -> PrefixHit:
        """Longest cached prefix of ``tokens`` → :class:`PrefixHit`.

        Pure probe: no refcounts move, no LRU stamps change, no stats are
        recorded (the scheduler records exactly one lookup per *admission*
        via :meth:`grant`, so a head-of-queue request re-probed while it
        waits does not distort the hit rate)."""
        ps = self.page_size
        toks = [int(t) for t in tokens]
        limit = len(toks) - 1                   # always leave one cold token
        node = self.root
        nodes: List[_Node] = []
        d = 0
        while (d + 1) * ps <= limit:
            child = node.children.get(tuple(toks[d * ps:(d + 1) * ps]))
            if child is None:
                break
            nodes.append(child)
            node = child
            d += 1
        partial = 0
        rest = toks[d * ps:limit]
        if rest:
            best, best_lcp = None, 0
            for blk, child in node.children.items():
                lcp = 0
                for a, b in zip(rest, blk):
                    if a != b:
                        break
                    lcp += 1
                if lcp > best_lcp:
                    best, best_lcp = child, lcp
            if best is not None:
                nodes.append(best)
                partial = best_lcp
        return PrefixHit(pages=tuple(n.page for n in nodes),
                         tokens=d * ps + partial, partial_rows=partial,
                         nodes=tuple(nodes))

    def grant(self, hit: PrefixHit, total_tokens: int) -> None:
        """Commit a hit to an admitted request: one pool reference per
        granted page, LRU touch down the path, and the per-admission stats
        (``total_tokens`` = the request's known tokens, hit or not)."""
        self.lookups += 1
        self.lookup_tokens += total_tokens
        if self.obs is not None:
            self.obs.prefix_lookup(total_tokens, hit.tokens, len(hit.pages))
        if not hit.tokens:
            return
        stamp = next(self._clock)
        for node in hit.nodes:
            self.kv.share(node.page)
            node.stamp = stamp
        self.hits += 1
        self.hit_tokens += hit.tokens
        self.shared_page_grants += len(hit.pages)
        self.partial_hits += int(hit.partial_rows > 0)

    # ------------------------------------------------------------- publish
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Publish a request's full pages: ``pages[i]`` holds rows for
        ``tokens[i·ps:(i+1)·ps]``.  Existing nodes win (first publisher
        keeps the canonical page; the duplicate stays with its request and
        frees normally); new nodes take a cache reference so the page
        survives its request.  → number of pages newly cached."""
        ps = self.page_size
        node = self.root
        stamp = next(self._clock)
        new = 0
        for i in range(len(tokens) // ps):
            blk = tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
            child = node.children.get(blk)
            if child is None:
                child = _Node(blk, int(pages[i]), node, stamp)
                node.children[blk] = child
                self._nodes[int(pages[i])] = child
                self.kv.share(int(pages[i]))
                new += 1
            else:
                child.stamp = stamp
            node = child
        self.inserted_pages += new
        if new:
            self.version += 1
        return new

    # ------------------------------------------------------------ eviction
    def evict_one(self) -> bool:
        """Reclaim the LRU unreferenced **leaf**: page to the free heap,
        node out of the tree (its parent becomes the next leaf candidate).
        Never touches a page any request references.  → False when nothing
        is reclaimable."""
        best = None
        for node in self._nodes.values():
            if node.children or self.kv.ref[node.page] != 1:
                continue
            if best is None or node.stamp < best.stamp:
                best = node
        if best is None:
            return False
        self._drop(best)
        self.kv.release_one(best.page)
        return True

    def release_hold(self, page: int) -> bool:
        """Drop the cache's own reference on a *leaf* node so its one other
        holder becomes the exclusive owner — the scheduler's last resort
        when a CoW would demand a page the pool cannot produce.  Non-leaf
        nodes refuse (evicting them would strand their descendants)."""
        node = self._nodes.get(page)
        if node is None or node.children:
            return False
        self._drop(node)
        self.kv.release_one(page)         # other holders keep it resident
        return True

    def _drop(self, node: _Node) -> None:
        del node.parent.children[node.block]
        del self._nodes[node.page]
        self.evicted_pages += 1
        self.version += 1
        if self.obs is not None:
            self.obs.prefix_evicted()

    def enforce_budget(self) -> None:
        """Shrink to ``max_pages`` resident cached pages (LRU leaf-first);
        pages pinned by live requests are skipped and re-tried at the next
        publish/release."""
        if self.max_pages is None:
            return
        while self.cached_pages > self.max_pages and self.evict_one():
            pass
