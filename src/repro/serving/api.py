"""Request-level serving API: the types every serving layer speaks.

The serving surface is three nouns and one verb:

- :class:`Request` — what a client submits (prompt, budget, sampling) and
  what comes back (``tokens``, ``state``);
- :class:`~repro.serving.scheduler.Scheduler` — decides, each step, which
  requests run and how many tokens each contributes (continuous batching,
  chunked prefill, preemption-by-eviction);
- :class:`~repro.serving.core.EngineCore` — owns the page pool and the one
  jitted step function; ``EngineCore.step()`` executes the scheduler's plan
  and returns a :class:`StepOutput`.

There is deliberately no prefill/decode split in the API: a request is a
stream of known tokens (prompt ⊕ generated) whose KV rows are written
through the same paged step in chunks — decode is simply the chunk of
length one that follows once every known token's row is resident.  That is
HASTILY's linear-in-length pipelining restated at the request level.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.sampling import InvalidRequest, SamplingParams


class RequestState(str, enum.Enum):
    """Observable lifecycle of a request (informational; the scheduler's
    actual bookkeeping is rows-written vs tokens-known)."""
    WAITING = "waiting"        # submitted, not yet holding a lane
    PREFILL = "prefill"        # resident; prompt rows still streaming in
    DECODE = "decode"          # resident; one new token per step
    PREEMPTED = "preempted"    # evicted mid-flight; will resume by replay
    FINISHED = "finished"
    ABORTED = "aborted"        # cancelled by the client; pages released


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens``/``done``/``state`` are filled by
    the engine; everything else is client input.

    ``sampling`` is the authoritative per-request sampling record
    (:class:`~repro.serving.sampling.SamplingParams`).  The legacy
    ``temperature`` field survives as a constructor shorthand — when
    ``sampling`` is omitted it seeds a default record, and afterwards the
    two are kept in sync (scheduler policy like the speculative
    greedy-lanes-only gate reads whichever is convenient).  Invalid
    budgets/params raise :class:`~repro.serving.sampling.InvalidRequest`
    at construction, never mid-serve."""
    uid: int
    prompt: np.ndarray                 # (Lp,) int32
    max_new: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: RequestState = RequestState.WAITING

    def __post_init__(self):
        if self.sampling is None:
            self.sampling = SamplingParams(temperature=self.temperature)
        self.temperature = self.sampling.temperature
        if self.sampling.max_tokens is not None:
            self.max_new = min(self.max_new, self.sampling.max_tokens)
        if self.max_new <= 0:
            raise InvalidRequest("max_new", f"must be >= 1, got "
                                 f"{self.max_new}", uid=self.uid)

    def known_tokens(self) -> np.ndarray:
        """prompt ⊕ generated — every token whose KV row must eventually be
        resident.  The scheduler schedules nothing else: a request is a
        cursor into this stream (preemption just rewinds the cursor)."""
        return np.concatenate(
            [np.asarray(self.prompt, np.int64),
             np.asarray(self.tokens, np.int64)]).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class StepOutput:
    """What one ``EngineCore.step()`` did."""
    tokens: Dict[int, int]             # uid → token sampled this step
    finished: Tuple[int, ...]          # uids completed this step
    preempted: Tuple[int, ...]         # uids evicted by this step's schedule
    lanes: int                         # lanes that ran (q_len > 0)
    # Phase split is by remaining-known at planning (RequestState), not by
    # q_len: a chunk_size=1 engine still streams *prefill* rows one at a
    # time, and only the step that consumes the final known token (and
    # samples) counts as decode.
    prefill_tokens: int                # prompt-stream chunk tokens
    decode_tokens: int                 # sampling-step lanes
    # Padding-tax accounting: the step's live token rows vs the token rows
    # the jitted step actually computed (padded (lanes, C) block or bucketed
    # ragged stream).  live_rows / padded_rows is the step's padding
    # efficiency; the bench aggregates it per run.
    live_rows: int = 0
    padded_rows: int = 0
    # Prefix-cache accounting: known tokens granted from resident shared
    # pages at this step's admissions — rows the engine will never stream
    # because their KV already sits in the pool (0 with the cache off).
    prefix_hit_tokens: int = 0
    # Speculative-decoding accounting (0 unless the engine drafts): drafted
    # rows this step streamed past the known tokens, and how many of them
    # the verify accepted.  A drafting lane commits 1 + its accepted drafts
    # tokens in one step; ``accepted / steps`` is the bench's
    # accepted-tokens-per-step metric.
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # Resolved varlen-kernel block shapes the ragged step ran with (the
    # autotuner's ``KernelConfig.describe()`` dict: block_q, block_pages,
    # dequant, source ∈ {"default", "tuned"}) — recorded per step so bench
    # regressions are attributable to the config that produced them.  None
    # for the padded oracle mode.
    kernel_config: Optional[Dict[str, Any]] = None

    @property
    def mixed(self) -> bool:
        """True when chunked prefill and decode shared this batch."""
        return self.prefill_tokens > 0 and self.decode_tokens > 0


class UnsupportedCacheLayout(ValueError):
    """A model's cache pytree cannot be paged.

    Raised at construction (never mid-serve) with the offending ``layout``
    name attached: ``"ring_buffer_sliding_window"`` (local-attention ring
    caches are already O(window) — paging them would break the slot = pos
    mod window invariant) or ``"ssm_state"`` (O(1) per-slot state: no
    length axis to page).  Serve these configs with the slot-contiguous
    ``ServingEngine``.
    """

    def __init__(self, layout: str, model: str, detail: str):
        self.layout = layout
        super().__init__(
            f"paged KV cache: {model} uses an unpageable cache layout "
            f"[{layout}]: {detail} — serve this config with the "
            f"slot-contiguous ServingEngine")
