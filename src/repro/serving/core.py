"""EngineCore: one ``step()`` drives every serving phase through the pool.

The engine owns three things: the page pool (``PagedKVCache``), the
scheduler, and **one** jitted step function

    step(params, pool, table, tokens, kv_len, q_len) → (logits, pool)

over a right-aligned ``(lanes, C)`` token block — per lane, ``q_len`` live
tokens ending at row ``kv_len - 1``; dead rows are left-padding whose KV
writes land on the pool's scratch page.  A decode lane is ``q_len == 1``, a
chunked-prefill lane streams ``q_len ≤ C`` prompt tokens, an idle lane is
``q_len == 0``; all of them share the batch, so chunked prefill and decode
pipeline through the *same* step — the paper's fine-grained
attention/FFN pipelining (PAPER.md §pipelining) applied at the serving
level.  C is ``1`` for decode-only steps and ``chunk_size`` whenever any
lane prefills, and the page table is padded to a power-of-two width, so a
stream of arbitrary prompt lengths compiles O(1) step functions — the old
per-prompt-length prefill buckets (and their recompile storm) are gone,
along with the contiguous-prefill-then-scatter ``write_prefill`` copy.

Sampling stays on the host: greedy picks break exact logit ties to the
lowest token id (reproducible across engines and platforms), temperature
sampling draws from a per-engine PRNG stream.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.paged import PagedKVCache
from repro.serving.scheduler import Scheduler


def greedy_token(logits: jax.Array) -> int:
    """Deterministic greedy pick: the *lowest* index among joint maxima.

    ``argmax`` tie behaviour is backend-defined; serving promises
    reproducible token streams across engines and platforms, so exact
    logit ties break to the lowest token id explicitly.
    """
    lg = jnp.asarray(logits)
    v = lg.shape[-1]
    hit = lg == jnp.max(lg)
    return int(jnp.min(jnp.where(hit, jnp.arange(v), v)))


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> tuple:
    """One host-side sample shared by every engine → (token, next key).

    Greedy (temperature ≤ 0) is the lowest-index tie-break above; any
    change to sampling must stay in this one place or the engines' promised
    cross-engine token identity silently diverges.
    """
    if temperature <= 0.0:
        return greedy_token(logits), key
    key, sub = jax.random.split(key)
    return int(jax.random.categorical(sub, logits / temperature)), key


class EngineCore:
    """Request-level serving engine (see module doc).

    Lifecycle: ``submit(Request)`` → repeated ``step()`` (each returns a
    :class:`StepOutput`) → finished requests accumulate in ``finished``.
    ``run()`` drains everything.  Construction raises
    :class:`~repro.serving.api.UnsupportedCacheLayout` for cache families
    that cannot page (ring-buffer sliding windows, SSM state) — serve those
    with the slot-contiguous ``ServingEngine``.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, lanes: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 chunk_size: int = 16, max_len: Optional[int] = None,
                 step_tokens: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.prefill_chunk_paged is None:
            # Typed like the pool's rejections so launchers can catch
            # narrowly instead of swallowing every ValueError.
            raise UnsupportedCacheLayout(
                "no_paged_step", cfg.name,
                f"the {cfg.family} family exposes no paged chunk step")
        self.params = params
        self.lanes = lanes
        self.max_len = max_len or num_pages * page_size
        self.kv = PagedKVCache(self.model, num_pages, page_size)
        self.scheduler = Scheduler(self.kv, lanes=lanes,
                                   chunk_size=chunk_size,
                                   step_tokens=step_tokens)
        self.chunk_size = chunk_size
        self.key = jax.random.PRNGKey(seed)
        self.finished: List[Request] = []
        self.trace_count = 0            # step-fn retraces (compile counter)

        m = self.model

        def step_fn(params, pool, tbl, toks, kv_len, q_len):
            self.trace_count += 1       # python side effect: counts traces
            return m.prefill_chunk_paged(params, toks, pool, tbl,
                                         kv_len, q_len)

        # donated pool: every layer's row writes update in place instead of
        # copying the whole pool each step.
        self._step = jax.jit(step_fn, donate_argnums=(1,))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}")
        self.scheduler.submit(req)

    def _sample(self, logits: jax.Array, temperature: float) -> int:
        tok, self.key = sample_token(logits, temperature, self.key)
        return tok

    def step(self) -> StepOutput:
        """Schedule → one batched model call → sample/finish.  All phases —
        chunked prefill, decode, admission, preemption — happen here."""
        plans, preempted = self.scheduler.schedule()
        if not plans:
            return StepOutput(tokens={}, finished=(), preempted=preempted,
                              lanes=0, prefill_tokens=0, decode_tokens=0)
        c = 1 if all(p.q_len == 1 for p in plans) else self.chunk_size
        width = max(len(p.run.pages) for p in plans)
        width = 1 << max(width - 1, 0).bit_length()    # retrace bucketing
        b, scratch = self.lanes, self.kv.scratch

        toks = np.zeros((b, c), np.int32)
        kv_len = np.zeros((b,), np.int32)
        q_len = np.zeros((b,), np.int32)
        tbl = np.full((b, width), scratch, np.int32)
        for i, p in enumerate(plans):
            toks[i, c - p.q_len:] = p.run.next_tokens(p.q_len)
            kv_len[i] = p.run.rows + p.q_len
            q_len[i] = p.q_len
            tbl[i, :len(p.run.pages)] = p.run.pages

        logits, self.kv.pool = self._step(
            self.params, self.kv.pool, jnp.asarray(tbl), jnp.asarray(toks),
            jnp.asarray(kv_len), jnp.asarray(q_len))

        out_tokens = {}
        finished = []
        # Phase comes from the scheduler (remaining-known at planning), not
        # from q_len: a chunk_size=1 engine still streams *prefill* rows one
        # at a time, and only the remaining==1 step is a decode.
        n_prefill = sum(p.q_len for p in plans
                        if p.run.req.state is RequestState.PREFILL)
        n_decode = sum(1 for p in plans
                       if p.run.req.state is RequestState.DECODE)
        for i, p in enumerate(plans):
            run, req = p.run, p.run.req
            sample = p.sample             # before the cursor moves
            run.rows += p.q_len
            if not sample:
                continue
            tok = self._sample(logits[i], req.temperature)
            req.tokens.append(int(tok))
            out_tokens[req.uid] = int(tok)
            if (len(req.tokens) >= req.max_new
                    or (req.eos_id is not None and int(tok) == req.eos_id)):
                req.done = True
                finished.append(req.uid)
                self.finished.append(req)
                self.scheduler.finish(run)
        return StepOutput(tokens=out_tokens, finished=tuple(finished),
                          preempted=preempted, lanes=len(plans),
                          prefill_tokens=n_prefill, decode_tokens=n_decode)

    def run(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while self.scheduler.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving did not drain")
        return self.finished

    # -------------------------------------------------------- introspection
    @property
    def pages_in_use(self) -> int:
        return self.kv.num_pages - len(self.kv.free)

    @property
    def page_tables(self) -> List[List[int]]:
        """Live page table per resident request (scheduler ticket order)."""
        return [list(r.pages) for r in self.scheduler.running]
