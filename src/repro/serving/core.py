"""EngineCore: one ``step()`` drives every serving phase through the pool.

The engine owns three things: the page pool (``PagedKVCache``), the
scheduler, and one jitted step function per packing mode:

- ``mode="ragged"`` (default) — the token-level packed stream

      step(params, pool, token_pages, tokens, pos, last_idx,
           cu, temperature, top_k, top_p, seed, counter)
          → (tokens (lanes,), pool)

  The scheduler flattens the step into ``T = Σ live tokens`` dense rows
  (``RaggedBatch``): lane segments abut, each token carries its own
  position and page-table row, and T is bucketed to a few widths (powers
  of two plus 3/2 midpoints) with prefill chunks trimmed to land live work
  exactly on a bucket edge.  A step with 3 decode lanes and one 64-token
  prefill chunk costs ~67 token-rows of compute — not 4 × 64, which is
  what the padded block pays.  Every scheduled row is (almost always) live
  work: the paper's never-stall-on-padding pipelining (PAPER.md §IV)
  applied to the serving batch itself.  The stream's lane boundaries
  (``cu_seqlens``, dead padding rows covered by a trailing pseudo-segment)
  ride into the step as a real compute input: the varlen kernel tiles the
  stream into q-blocks of ``block_q`` same-lane rows, so a prefill chunk
  reads each KV page once per *block*, not once per token — full-width
  steps need no padded-block special case anymore (that dispatch is
  retired; ``mode="padded"`` survives only as the equivalence oracle).
  Block shapes come from the kernel autotuner's per-(model, platform)
  table (``kernels/autotune.py``), resolved once at engine construction
  and recorded in every ``StepOutput``.

- ``mode="padded"`` — the PR-3 right-aligned ``(lanes, C)`` block

      step(params, pool, table, tokens, kv_len, q_len) → (logits, pool)

  per lane ``q_len`` live tokens ending at row ``kv_len - 1``, dead rows
  left-padding.  C is 1 for decode-only steps and ``chunk_size`` whenever
  any lane prefills.  Kept as the equivalence oracle the ragged step is
  proven against (token-identical on the same traces, float and int8).

Both modes trace O(1) step functions across arbitrary prompt-length
streams — shapes are keyed by (width bucket × power-of-two table width),
never by prompt length.

Sampling lives *inside* the jitted ragged step (``serving/sampling.py``):
the step returns per-lane int32 tokens, drawn in one vectorized pass over
the ``last_idx`` logits — temperature-scale → top-k/top-p mask → Gumbel-max
categorical over the LUT log-softmax scores — with a private PRNG key per
request, ``fold_in(PRNGKey(sampling.seed), #generated)``.  Greedy
(temperature ≤ 0) reproduces the host-side lowest-index tie-break exactly,
so the speculative verify rule and every cross-engine equivalence suite
are unchanged.  The padded oracle mode still extracts (lanes, V) logits
and draws on the host through :func:`~repro.serving.sampling.sample_row`
— the *same* kernel on one row, so both modes share one sampling
semantics.

PRNG migration (PR 8): earlier revisions advanced one per-engine
``self.key`` on every sampled lane, which made a request's stream depend
on every other request the engine had ever served (and on lane placement).
That key is gone; seeds are per-request (``SamplingParams.seed``) and the
token stream is batch-invariant — identical whether the request runs
alone, co-batched, or resumes after preemption.  The engine's ``seed``
constructor arg is accepted but unused (kept so existing callers don't
break); :func:`sample_token` survives only as the deprecated host-key
form for code that still threads its own key.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.paged import PagedKVCache
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.sampling import (InvalidRequest, sample_row, stop_hit,
                                    validate_stop_tokens)
from repro.serving.scheduler import Scheduler
from repro.serving.spec import NGramProposer
from repro.serving.tracing import ServingObservability


def greedy_token(logits: jax.Array) -> int:
    """Deterministic greedy pick: the *lowest* index among joint maxima.

    ``argmax`` tie behaviour is backend-defined; serving promises
    reproducible token streams across engines and platforms, so exact
    logit ties break to the lowest token id explicitly.
    """
    lg = jnp.asarray(logits)
    v = lg.shape[-1]
    hit = lg == jnp.max(lg)
    return int(jnp.min(jnp.where(hit, jnp.arange(v), v)))


def greedy_tokens(logits: np.ndarray) -> np.ndarray:
    """Vectorised :func:`greedy_token` over leading axes: (..., V) → (...,).

    The speculative verify rule is *argmax equality* against this exact
    pick, row by row — max is an exact float op, so the batched numpy form
    here and the per-row jax form above agree bit-for-bit on the same
    logits, which is what makes accepted drafts token-identical to the
    sequential greedy stream.
    """
    lg = np.asarray(logits)
    v = lg.shape[-1]
    hit = lg == lg.max(axis=-1, keepdims=True)
    return np.min(np.where(hit, np.arange(v), v), axis=-1)


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> tuple:
    """Deprecated host-key sampling → (token, next key).

    This is the pre-PR-8 path: one shared key advanced per draw, which
    made token streams depend on co-batched traffic.  Engines now draw
    per-request via :func:`repro.serving.sampling.sample_row` (the
    single-lane oracle of the in-step kernel); this form is kept only for
    external callers that thread their own key.  Greedy (temperature ≤ 0)
    is still the lowest-index tie-break.
    """
    if temperature <= 0.0:
        return greedy_token(logits), key
    key, sub = jax.random.split(key)
    return int(jax.random.categorical(sub, logits / temperature)), key


class EngineCore:
    """Request-level serving engine (see module doc).

    Lifecycle: ``submit(Request)`` → repeated ``step()`` (each returns a
    :class:`StepOutput`) → finished requests accumulate in ``finished``.
    ``run()`` drains everything.  Construction raises
    :class:`~repro.serving.api.UnsupportedCacheLayout` for cache families
    that cannot page (ring-buffer sliding windows, SSM state) — serve those
    with the slot-contiguous ``ServingEngine``.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, lanes: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 chunk_size: int = 16, max_len: Optional[int] = None,
                 step_tokens: Optional[int] = None, mode: str = "ragged",
                 token_buckets: Optional[Any] = None,
                 prefix_cache: bool = False,
                 cache_pages: Optional[int] = None, seed: int = 0,
                 speculative: bool = False, spec_k: int = 4,
                 proposer: Any = None, kernel_config: Any = None,
                 mesh: Any = None, metrics: bool = True,
                 registry: Any = None, trace_ring: int = 512):
        if mode not in ("ragged", "padded"):
            raise ValueError(f"unknown EngineCore mode {mode!r}; "
                             f"expected 'ragged' or 'padded'")
        # Tensor-parallel serving (opt-in): ``mesh`` is an int device count
        # or a jax Mesh with a "model" axis.  The page pool's KV-head axis
        # is sharded across it and the ragged step runs under shard_map —
        # each device attends its head band against its local pool shard
        # and one tiled all-gather rebuilds the head axis (HASTILY's
        # reduce-and-gather; docs/architecture.md).  All host-side state —
        # scheduler, page table, free heap, refcounts, prefix cache — is
        # mesh-oblivious, and mesh 1 (or None) takes the exact
        # single-device path: no shard_map, identical jaxpr.
        self.mesh = self._resolve_mesh(mesh)
        if self.mesh is not None:
            n = self.mesh.shape["model"]
            if mode != "ragged":
                raise ValueError("mesh > 1 requires mode='ragged' (the "
                                 "padded oracle step is single-device)")
            if cfg.num_heads % n or cfg.num_kv_heads % n:
                raise ValueError(
                    f"mesh of {n} devices must divide num_heads="
                    f"{cfg.num_heads} and num_kv_heads={cfg.num_kv_heads}")
        if speculative and mode != "ragged":
            # The verify step IS the ragged step (drafted rows ride the
            # packed stream); the padded block extracts last-row logits
            # only and has no lane room for 1 + k chunks.
            raise ValueError("speculative decoding requires mode='ragged'")
        if speculative and spec_k < 1:
            raise ValueError(f"speculative decoding needs spec_k >= 1, "
                             f"got {spec_k}")
        self.cfg = cfg
        self.mode = mode
        self.model = build_model(cfg)
        if self.model.prefill_chunk_paged is None or (
                mode == "ragged" and self.model.step_ragged is None):
            # Typed like the pool's rejections so launchers can catch
            # narrowly instead of swallowing every ValueError.
            raise UnsupportedCacheLayout(
                "no_paged_step", cfg.name,
                f"the {cfg.family} family exposes no paged chunk step")
        self.params = params
        self.lanes = lanes
        self.max_len = max_len or num_pages * page_size
        # One observability bundle for the whole stack (serving/tracing.py):
        # registry + request spans + step ring + the retrace sentinel.  All
        # hooks are host-side no-ops when ``metrics=False`` (the bench's
        # overhead A/B arm); ``registry=`` lets several engines share one.
        self.obs = ServingObservability(enabled=metrics, registry=registry,
                                        ring_capacity=trace_ring)
        self.kv = PagedKVCache(self.model, num_pages, page_size,
                               obs=self.obs)
        self._pool_specs = None
        if self.mesh is not None:
            # Shard the pool's KV-head axis; page ids stay whole on every
            # device, so all host-side page accounting is untouched.
            # Params are replicated once here (not per step call).
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.parallel.sharding import pool_specs, shard_tree
            self._pool_specs = pool_specs(self.kv.pool, self.mesh)
            self.kv.pool = shard_tree(self.kv.pool, self._pool_specs,
                                      self.mesh)
            self.params = jax.device_put(
                params, NamedSharding(self.mesh, PartitionSpec()))
        # Shared-prefix KV reuse (opt-in): admission probes a radix cache of
        # page-aligned token blocks and grants resident pages for the hit
        # prefix; chunked prefill then starts at the first cold token.
        # Token streams are identical with the cache on or off (the prefix
        # pages hold the exact KV the skipped chunks would have written).
        self.prefix_cache = (RadixPrefixCache(self.kv, max_pages=cache_pages,
                                              obs=self.obs)
                             if prefix_cache else None)
        # Speculative decoding (opt-in): a host-side proposer drafts up to
        # spec_k tokens per greedy decode lane; the scheduler streams the
        # drafted chunk through the same ragged step, the engine verifies
        # every drafted position against its own argmax in that one step,
        # and commit/rollback happens in _finish.  Token streams are
        # identical with speculation on or off (the acceptance rule is
        # argmax equality against the exact greedy pick).
        self.speculative = speculative
        self.spec_k = spec_k if speculative else 0
        self.proposer = (proposer if proposer is not None
                         else NGramProposer(obs=self.obs)) \
            if speculative else None
        self.scheduler = Scheduler(self.kv, lanes=lanes,
                                   chunk_size=chunk_size,
                                   step_tokens=step_tokens,
                                   token_buckets=token_buckets,
                                   prefix_cache=self.prefix_cache,
                                   spec_k=self.spec_k,
                                   proposer=self.proposer,
                                   obs=self.obs)
        # Varlen-kernel block shapes: explicit override, else the
        # autotuner's persisted per-(model, platform) table, else the
        # hardcoded default.  Static for the engine's lifetime — the jitted
        # ragged step closes over it, so swapping configs means a new
        # engine (per-engine jit caches keep old traces from leaking).
        from repro.kernels.autotune import resolve_config
        self.kernel_config = (kernel_config if kernel_config is not None
                              else resolve_config(cfg.name))
        self.chunk_size = chunk_size
        del seed   # per-request now (SamplingParams.seed); see module doc
        self.finished: List[Request] = []
        self.trace_count = 0            # step-fn retraces (compile counter)
        self.drafted_total = 0          # speculative telemetry, lifetime
        self.accepted_total = 0
        self.spec_steps = 0             # steps that carried ≥ 1 draft

        m = self.model

        def step_fn(params, pool, tbl, toks, kv_len, q_len):
            self.trace_count += 1       # python side effect: counts traces
            self.obs.step_traced()      # retrace sentinel (tracing.py)
            return m.prefill_chunk_paged(params, toks, pool, tbl,
                                         kv_len, q_len)

        kc = self.kernel_config
        tp_axis = None if self.mesh is None else "model"

        def ragged_fn(params, pool, token_pages, toks, pos, last_idx, cu,
                      temperature, top_k, top_p, seed, counter):
            self.trace_count += 1       # python side effect: counts traces
            self.obs.step_traced()      # retrace sentinel (tracing.py)
            # The five (lanes,) sampling arrays are traced data — a new
            # temperature/seed can never be a retrace key — and the step
            # returns tokens, not logits: selection happens in-graph.
            return m.step_ragged(params, toks, pool, token_pages, pos,
                                 last_idx, cu_seqlens=cu, kernel_config=kc,
                                 sampling=dict(temperature=temperature,
                                               top_k=top_k, top_p=top_p,
                                               seed=seed, counter=counter),
                                 tp_axis=tp_axis)

        if self.mesh is not None:
            # One shard_map around the whole step: pool leaves arrive as
            # local head-band shards, everything else replicated.  The
            # sampled tokens are a deterministic function of replicated
            # inputs (the all-gather rebuilt the head axis before wo), so
            # every device computes identical picks — out_specs P() is
            # sound without a check pass (check=False: 0.4.x's rep checker
            # cannot see through the kernel's custom calls).
            from jax.sharding import PartitionSpec
            from repro.parallel import compat
            rep = PartitionSpec()
            ragged_fn = compat.shard_map(
                ragged_fn, mesh=self.mesh,
                in_specs=(rep, self._pool_specs) + (rep,) * 10,
                out_specs=(rep, self._pool_specs), check=False)

        # donated pool: every layer's row writes update in place instead of
        # copying the whole pool each step.
        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self._ragged = (None if self.model.step_ragged is None
                        else jax.jit(ragged_fn, donate_argnums=(1,)))
        self.obs.g_mesh.set(self.mesh_size)
        self.obs.g_coll_per_tok.set(self.collective_bytes_per_token)

    @staticmethod
    def _resolve_mesh(mesh):
        """Normalise the ``mesh`` arg: None / 1 / a size-1 Mesh → None (the
        exact single-device path — no shard_map anywhere near the graph);
        an int N > 1 → a 1×N ``("model",)`` mesh over the first N devices;
        a jax Mesh with a "model" axis passes through."""
        if mesh is None:
            return None
        if isinstance(mesh, int):
            if mesh <= 1:
                return None
            if len(jax.devices()) < mesh:
                raise ValueError(
                    f"mesh of {mesh} devices requested but only "
                    f"{len(jax.devices())} visible (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count for CPU tests)")
            from repro.parallel import compat
            return compat.make_mesh((mesh,), ("model",))
        if "model" not in mesh.axis_names:
            raise ValueError(f"serving mesh needs a 'model' axis, got "
                             f"{mesh.axis_names}")
        return mesh if mesh.size > 1 else None

    # ------------------------------------------------------------------ API
    def validate(self, req: Request) -> None:
        """Engine-dependent request validation (construction already checked
        everything self-contained): budget vs ``max_len``/pool, stop-token
        ids vs the vocab.  Raises :class:`InvalidRequest`; never admits.
        The async front door calls this eagerly so a bad request fails in
        the client's own context instead of mid-serve."""
        if len(req.prompt) + req.max_new > self.max_len:
            raise InvalidRequest(
                "max_new", f"prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}", uid=req.uid)
        if len(req.prompt) == 0:
            raise InvalidRequest("prompt", "empty prompt", uid=req.uid)
        validate_stop_tokens(req.sampling, self.cfg.vocab_size, uid=req.uid)

    def submit(self, req: Request) -> None:
        self.validate(req)
        self.scheduler.submit(req)

    def abort(self, uid: int) -> bool:
        """Cancel a request (client disconnect / explicit cancel).

        Waiting requests leave the queue; a mid-flight request releases its
        lane and pages *immediately* — full pages are published to the
        prefix cache first (the computed KV stays reusable), exactly the
        :meth:`Scheduler.finish` dataflow.  Returns False for unknown /
        already-finished uids.  The freed lane admits new work next step;
        an abort can never wedge a lane.
        """
        return self.scheduler.abort(uid)

    def step(self) -> StepOutput:
        """Schedule → one batched model call → sample/finish.  All phases —
        chunked prefill, decode, admission, preemption — happen here; the
        engine's ``mode`` picks the packing (ragged stream / padded block),
        the token streams are identical either way."""
        t0 = time.perf_counter()
        out = (self._step_ragged() if self.mode == "ragged"
               else self._step_padded())
        s = self.scheduler
        self.obs.record_step(
            out, dur_ms=(time.perf_counter() - t0) * 1e3,
            sched=s, kv=self.kv, cache=self.prefix_cache,
            table_pages=s._table_pages,
            trimmed_prefill=s.trimmed_prefill_step,
            trimmed_drafts=s.trimmed_draft_step,
            width=out.padded_rows)
        return out

    def _step_padded(self) -> StepOutput:
        """The PR-3 right-aligned (lanes, C) block step (oracle mode)."""
        plans, preempted = self.scheduler.schedule()
        return self._run_block(plans, preempted)

    def _step_ragged(self) -> StepOutput:
        """The token-level step (default mode): one packed stream, always.

        Full-width steps (all-lanes decode, all-lanes full prefill chunks)
        used to dispatch to the padded block because the varlen kernel read
        each KV page once per *token* where the block form read it once per
        chunk.  The q-block-tiled varlen dataflow closed that gap — each
        page is read once per ``block_q`` rows regardless of how ragged the
        step is — so every ragged step now runs the one varlen kernel and
        the padded block survives only as ``mode="padded"``, the
        equivalence oracle.  Token streams are identical either way.
        """
        s = self.scheduler
        wants = s.begin_step()
        batch, preempted = s.batch_for(wants)
        return self._run_stream(batch, preempted)

    def _run_block(self, plans, preempted) -> StepOutput:
        """Execute lane plans as one right-aligned (lanes, C) block."""
        if not plans:
            return StepOutput(
                tokens={}, finished=(), preempted=preempted, lanes=0,
                prefill_tokens=0, decode_tokens=0,
                prefix_hit_tokens=self.scheduler.prefix_hit_tokens_step)
        c = 1 if all(p.q_len == 1 for p in plans) else self.chunk_size
        width = max(len(p.run.pages) for p in plans)
        width = 1 << max(width - 1, 0).bit_length()    # retrace bucketing
        b, scratch = self.lanes, self.kv.scratch

        toks = np.zeros((b, c), np.int32)
        kv_len = np.zeros((b,), np.int32)
        q_len = np.zeros((b,), np.int32)
        tbl = np.full((b, width), scratch, np.int32)
        for i, p in enumerate(plans):
            toks[i, c - p.q_len:] = p.stream_tokens()
            kv_len[i] = p.run.rows + p.q_len
            q_len[i] = p.q_len
            tbl[i, :len(p.run.pages)] = p.run.pages

        logits, self.kv.pool = self._step(
            self.params, self.kv.pool, jnp.asarray(tbl), jnp.asarray(toks),
            jnp.asarray(kv_len), jnp.asarray(q_len))
        return self._finish(plans, preempted, logits=logits,
                            live=int(sum(p.q_len for p in plans)),
                            padded=b * c)

    def _run_stream(self, batch, preempted) -> StepOutput:
        """Execute a RaggedBatch as one packed token stream."""
        plans = batch.plans
        if not plans:
            return StepOutput(
                tokens={}, finished=(), preempted=preempted, lanes=0,
                prefill_tokens=0, decode_tokens=0,
                prefix_hit_tokens=self.scheduler.prefix_hit_tokens_step)
        # Stream index of each plan's final token; idle tail lanes point at
        # row 0 (their logits are computed but never read — the (lanes, V)
        # output shape stays static across schedules).  Speculative engines
        # always pass the (lanes, 1 + spec_k) form — row j of lane i is the
        # lane's decode row plus its j-th drafted row, clamped to the last
        # real draft — so the verify extraction is one static-shape gather:
        # k stays a compile-time constant and trace count stays O(1)
        # whether a step carries 0 or k drafts.
        if self.speculative:
            last_idx = np.zeros((self.lanes, self.spec_k + 1), np.int32)
            ramp = np.arange(self.spec_k + 1, dtype=np.int32)
            for i, p in enumerate(plans):
                d = len(p.drafts)
                base = int(batch.cu_seqlens[i + 1]) - 1 - d
                last_idx[i] = base + np.minimum(ramp, d)
        else:
            last_idx = np.zeros((self.lanes,), np.int32)
            last_idx[:len(plans)] = batch.cu_seqlens[1:] - 1

        # Lane boundaries as a compute input, static (lanes + 2,) shape:
        # the live plans' boundaries, then the bucket's dead padding rows
        # as one trailing pseudo-segment ending at T (so cu[-1] == T — the
        # kernel's validated packing contract), then zero-width repeats.
        cu = np.full((self.lanes + 2,), batch.width, np.int32)
        cu[:len(batch.cu_seqlens)] = batch.cu_seqlens

        picks, self.kv.pool = self._ragged(
            self.params, self.kv.pool, jnp.asarray(batch.table),
            jnp.asarray(batch.tokens), jnp.asarray(batch.pos),
            jnp.asarray(last_idx), jnp.asarray(cu),
            *self._sampling_inputs(plans))
        return self._finish(plans, preempted, picks=np.asarray(picks),
                            live=batch.live, padded=batch.width)

    def _sampling_inputs(self, plans):
        """Per-lane sampling arrays for the in-step draw, all (lanes,).

        Idle tail lanes get temperature 0 (their greedy pick is computed
        but never read).  ``counter`` is the request's generated-token
        count — with ``seed`` it fully determines the lane's PRNG key, so
        the draw is batch-invariant and preemption-replay-stable.
        """
        n = self.lanes
        temp = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)       # 0 = off
        top_p = np.ones((n,), np.float32)      # 1 = off
        seed = np.zeros((n,), np.uint32)
        counter = np.zeros((n,), np.int32)
        for i, p in enumerate(plans):
            sp = p.run.req.sampling
            temp[i] = max(sp.temperature, 0.0)
            top_k[i] = sp.top_k or 0
            top_p[i] = 1.0 if sp.top_p is None else sp.top_p
            seed[i] = sp.seed or 0
            counter[i] = len(p.run.req.tokens)
        return (jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p),
                jnp.asarray(seed), jnp.asarray(counter))

    def _finish(self, plans, preempted, *, live: int, padded: int,
                picks=None, logits=None) -> StepOutput:
        """Shared step tail: advance cursors, commit/verify, retire finished.

        The ragged step hands back ``picks`` — per-lane tokens already
        drawn in-graph, (lanes,) or (lanes, 1+k) speculative; the padded
        oracle hands back ``logits`` and each sampling lane draws on the
        host through :func:`~repro.serving.sampling.sample_row` (the same
        kernel on one row).

        Non-speculative lanes commit exactly one token.  A drafting lane
        streamed ``1 + d`` rows; rows ≥ 1 of its picks are the in-graph
        greedy verify ``g[j]`` at every drafted position, and the lane
        commits ``g[0..acc]`` where ``acc`` is the longest prefix with
        ``g[j] == drafts[j]`` — exactly the tokens sequential greedy decode
        would have produced, one step at a time.  The cursor advances by
        ``base + (committed − 1)`` — the last committed token is *new* (its
        KV row is next step's mandatory write), the earlier ones already
        have their rows from this step — and :meth:`PagedKVCache.uncommit`
        returns any page holding only rejected rows, leaving pool state
        identical to never having drafted.

        Stop sequences are checked after every committed token (so a stop
        completed mid-way through a multi-token speculative commit — or
        across step boundaries — truncates at exactly the right token):
        the match is removed from the output and the rows cursor clamps to
        the surviving known tokens, keeping the prefix-cache publish
        KV-consistent.
        """
        out_tokens = {}
        finished = []
        # Phase comes from the scheduler (remaining-known at planning), not
        # from q_len: a chunk_size=1 engine still streams *prefill* rows one
        # at a time, and only the remaining==1 step is a decode.
        n_prefill = sum(p.q_len for p in plans
                        if p.run.req.state is RequestState.PREFILL)
        n_decode = sum(1 for p in plans
                       if p.run.req.state is RequestState.DECODE)
        lg = None if logits is None else np.asarray(logits)   # (lanes, V)
        drafted = sum(len(p.drafts) for p in plans)
        accepted = 0
        for i, p in enumerate(plans):
            run, req = p.run, p.run.req
            if not p.sample:
                run.rows += p.q_len
                continue
            base = p.q_len - len(p.drafts)
            if p.drafts:
                g = picks[i, :len(p.drafts) + 1]
                acc = 0
                while acc < len(p.drafts) and int(g[acc]) == p.drafts[acc]:
                    acc += 1
                commit = [int(t) for t in g[:acc + 1]]
            elif picks is not None:
                commit = [int(picks[i, 0] if picks.ndim == 2 else picks[i])]
            else:
                commit = [sample_row(lg[i], req.sampling, len(req.tokens))]
            done = stopped = False
            n = 0
            start = len(req.tokens)
            for tok in commit:        # eos/max_new/stop can cut this short
                req.tokens.append(tok)
                out_tokens[req.uid] = tok
                n += 1
                cut = stop_hit(req.tokens, req.sampling.stop)
                if cut is not None:
                    del req.tokens[cut:]     # stop match never surfaces
                    done = stopped = True
                    break
                if (len(req.tokens) >= req.max_new
                        or (req.eos_id is not None and tok == req.eos_id)):
                    done = True
                    break
            run.rows += base + n - 1
            if stopped:
                # Truncation may have swallowed every token this step
                # committed (and, for a stop spanning steps, earlier ones —
                # which is why streaming clients hold back stop prefixes,
                # see sampling.stop_holdback).  Report the last survivor of
                # this step, or nothing; clamp the rows cursor so _publish
                # never claims rows beyond the surviving known tokens.
                if len(req.tokens) > start:
                    out_tokens[req.uid] = req.tokens[-1]
                else:
                    out_tokens.pop(req.uid, None)
                run.rows = min(run.rows, run.known())
            self.obs.tokens_committed(req.uid, n, first=(start == 0))
            if p.drafts:
                accepted += n - 1
                self.obs.spec_verify(req.uid, len(p.drafts), n - 1)
                run.pages = self.kv.uncommit(run.pages, run.rows)
            if done:
                req.done = True
                finished.append(req.uid)
                self.finished.append(req)
                if self.proposer is not None and \
                        hasattr(self.proposer, "observe"):
                    self.proposer.observe(req.known_tokens())
                self.scheduler.finish(run)
        self.drafted_total += drafted
        self.accepted_total += accepted
        if drafted:
            self.spec_steps += 1
        return StepOutput(tokens=out_tokens, finished=tuple(finished),
                          preempted=preempted, lanes=len(plans),
                          prefill_tokens=n_prefill, decode_tokens=n_decode,
                          live_rows=live, padded_rows=padded,
                          prefix_hit_tokens=(
                              self.scheduler.prefix_hit_tokens_step),
                          drafted_tokens=drafted, accepted_tokens=accepted,
                          kernel_config=(self.kernel_config.describe()
                                         if self.mode == "ragged" else None))

    def run(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while self.scheduler.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving did not drain")
        return self.finished

    # -------------------------------------------------------- introspection
    @property
    def pages_in_use(self) -> int:
        return self.kv.num_pages - len(self.kv.free)

    @property
    def mesh_size(self) -> int:
        """Devices on the serving mesh's model axis (1 = single-device)."""
        return 1 if self.mesh is None else int(self.mesh.shape["model"])

    @property
    def collective_bytes_per_token(self) -> int:
        """Per-device bytes *received* by the step's collectives for each
        token-row streamed: one tiled head all-gather per attention layer,
        ``Hq · Dh · itemsize · (N−1)/N`` each.  Analytic (the dataflow has
        exactly this one collective), so the bench can report collective
        traffic without instrumenting the compiled step; 0 off-mesh.

        The gathered tensor is the *pre-projection attention output* — a
        float32 activation (the varlen kernel accumulates in f32 and the
        residual stream runs f32 over the narrow params), not a
        ``cfg.dtype`` value.  Pricing it at ``cfg.dtype`` was a silent 2×
        undercount on bf16 models, caught by the measured-HLO cross-check
        (:meth:`measure_collective_bytes`); casting the gather operand
        down to ``cfg.dtype`` would halve the real wire traffic but
        change sharded-vs-single-device numerics — an open ROADMAP item,
        not a bookkeeping choice."""
        n = self.mesh_size
        if n == 1:
            return 0
        per_layer = (self.cfg.num_heads * self.cfg.d_head
                     * jnp.dtype(jnp.float32).itemsize)
        return self.cfg.num_layers * per_layer * (n - 1) // n

    def measure_collective_bytes(self, width: Optional[int] = None) -> int:
        """*Measured* per-device collective wire bytes for one compiled
        ragged step, by walking the step's optimized HLO with
        :func:`repro.launch.hlo_analysis.hlo_totals` — the cross-check for
        the analytic :attr:`collective_bytes_per_token` (measured ≈
        analytic × stream width: every packed row, live or dead, runs the
        per-layer head all-gather).

        AOT: lowers and compiles the step at ``width`` (default: the
        widest token bucket) and the current table-width high-water mark
        without executing anything — but compiling *is* tracing, so call
        this before ``obs.mark_warm()`` or the sentinel counts it as a
        retrace.  Publishes the ``collective_bytes_per_step`` gauge;
        returns 0 off-mesh.
        """
        if self.mesh is None or self._ragged is None:
            self.obs.g_coll_per_step.set(0)
            return 0
        from repro.launch.hlo_analysis import hlo_totals
        t = int(width or self.scheduler.token_buckets[-1])
        pw = self.scheduler._table_pages
        lanes = self.lanes
        cu = np.full((lanes + 2,), t, np.int32)
        cu[0] = 0
        last_idx = (jnp.zeros((lanes, self.spec_k + 1), jnp.int32)
                    if self.speculative else jnp.zeros((lanes,), jnp.int32))
        args = (self.params, self.kv.pool,
                jnp.full((t, pw), self.kv.scratch, jnp.int32),
                jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
                last_idx, jnp.asarray(cu),
                jnp.zeros((lanes,), jnp.float32),
                jnp.zeros((lanes,), jnp.int32),
                jnp.ones((lanes,), jnp.float32),
                jnp.zeros((lanes,), jnp.uint32),
                jnp.zeros((lanes,), jnp.int32))
        try:
            # The trunk is a lax.scan over layer periods — one while loop
            # at depth 0 whose body must be multiplied by the trip count.
            from repro.models.lm import period_layout
            _, nper, _ = period_layout(self.cfg)
            hints = [int(nper)]
        except Exception:
            hints = None
        hlo = self._ragged.lower(*args).compile().as_text()
        total = int(hlo_totals(hlo, trip_hints=hints)["total_wire_bytes"])
        self.obs.g_coll_per_step.set(total)
        return total

    @property
    def prefix_stats(self) -> dict:
        """Prefix-cache telemetry (empty dict when the cache is off)."""
        return self.prefix_cache.stats() if self.prefix_cache else {}

    @property
    def spec_stats(self) -> dict:
        """Speculative-decoding telemetry (empty dict when not drafting).

        ``acceptance`` is accepted/drafted; ``accepted_per_spec_step`` is
        the extra tokens each drafting step committed beyond the one it
        would have anyway — the bench's headline number.
        """
        if not self.speculative:
            return {}
        return {
            "drafted_tokens": self.drafted_total,
            "accepted_tokens": self.accepted_total,
            "spec_steps": self.spec_steps,
            "acceptance": (self.accepted_total / self.drafted_total
                           if self.drafted_total else 0.0),
            "accepted_per_spec_step": (self.accepted_total / self.spec_steps
                                       if self.spec_steps else 0.0),
        }

    @property
    def page_tables(self) -> List[List[int]]:
        """Live page table per resident request (scheduler ticket order)."""
        return [list(r.pages) for r in self.scheduler.running]
