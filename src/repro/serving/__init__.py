from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.core import EngineCore
from repro.serving.engine import PagedServingEngine, ServingEngine
from repro.serving.metrics import (Counter, Gauge, Histogram,
                                   MetricsRegistry, start_metrics_server,
                                   write_metrics_json)
from repro.serving.paged import PagedKVCache
from repro.serving.prefix_cache import PrefixHit, RadixPrefixCache
from repro.serving.sampling import InvalidRequest, SamplingParams
from repro.serving.scheduler import (LanePlan, RaggedBatch, Scheduler,
                                     default_token_buckets)
from repro.serving.server import (AsyncLMServer, ServerClosed,
                                  ServerOverloaded)
from repro.serving.spec import NGramProposer
from repro.serving.tracing import (RequestSpan, RequestTracer,
                                   ServingObservability, SpanEvent,
                                   StepTraceRing)

__all__ = ["AsyncLMServer", "Counter", "EngineCore", "Gauge", "Histogram",
           "InvalidRequest", "LanePlan", "MetricsRegistry", "NGramProposer",
           "PagedKVCache", "PagedServingEngine", "PrefixHit",
           "RadixPrefixCache", "RaggedBatch", "Request", "RequestSpan",
           "RequestState", "RequestTracer", "SamplingParams", "Scheduler",
           "ServerClosed", "ServerOverloaded", "ServingEngine",
           "ServingObservability", "SpanEvent", "StepOutput",
           "StepTraceRing", "UnsupportedCacheLayout",
           "default_token_buckets", "start_metrics_server",
           "write_metrics_json"]
