from repro.serving.engine import (PagedServingEngine, Request, ServingEngine)
from repro.serving.paged import PagedKVCache

__all__ = ["PagedKVCache", "PagedServingEngine", "Request", "ServingEngine"]
