from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.core import EngineCore
from repro.serving.engine import PagedServingEngine, ServingEngine
from repro.serving.paged import PagedKVCache
from repro.serving.prefix_cache import PrefixHit, RadixPrefixCache
from repro.serving.scheduler import (LanePlan, RaggedBatch, Scheduler,
                                     default_token_buckets)
from repro.serving.spec import NGramProposer

__all__ = ["EngineCore", "LanePlan", "NGramProposer", "PagedKVCache",
           "PagedServingEngine", "PrefixHit", "RadixPrefixCache",
           "RaggedBatch", "Request", "RequestState", "Scheduler",
           "ServingEngine", "StepOutput", "UnsupportedCacheLayout",
           "default_token_buckets"]
