from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.core import EngineCore
from repro.serving.engine import PagedServingEngine, ServingEngine
from repro.serving.paged import PagedKVCache
from repro.serving.prefix_cache import PrefixHit, RadixPrefixCache
from repro.serving.sampling import InvalidRequest, SamplingParams
from repro.serving.scheduler import (LanePlan, RaggedBatch, Scheduler,
                                     default_token_buckets)
from repro.serving.server import (AsyncLMServer, ServerClosed,
                                  ServerOverloaded)
from repro.serving.spec import NGramProposer

__all__ = ["AsyncLMServer", "EngineCore", "InvalidRequest", "LanePlan",
           "NGramProposer", "PagedKVCache", "PagedServingEngine",
           "PrefixHit", "RadixPrefixCache", "RaggedBatch", "Request",
           "RequestState", "SamplingParams", "Scheduler", "ServerClosed",
           "ServerOverloaded", "ServingEngine", "StepOutput",
           "UnsupportedCacheLayout", "default_token_buckets"]
