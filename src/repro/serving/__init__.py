from repro.serving.api import (Request, RequestState, StepOutput,
                               UnsupportedCacheLayout)
from repro.serving.core import EngineCore
from repro.serving.engine import PagedServingEngine, ServingEngine
from repro.serving.paged import PagedKVCache
from repro.serving.scheduler import Scheduler

__all__ = ["EngineCore", "PagedKVCache", "PagedServingEngine", "Request",
           "RequestState", "Scheduler", "ServingEngine", "StepOutput",
           "UnsupportedCacheLayout"]
