"""Draft proposers for speculative decoding: n-gram / prompt-lookup drafts.

Decode is one token per step per lane, and at production batch sizes the
step is memory-bandwidth-bound on page-pool reads — the same traffic HASTILY
pipelines away (PAPER.md §IV).  Verifying ``k`` extra drafted tokens in the
same step re-reads no extra KV page per lane beyond the rows the drafts
themselves add, so a correct draft turns one step into ``1 + accepted``
committed tokens at almost the bandwidth of one.  The *draft* side needs no
model at all to start paying off: production streams are self-similar
(copying, templated answers, repeated queries), so a suffix match over
tokens the engine has already seen predicts the next few tokens often
enough to matter — prompt-lookup decoding, the zero-cost member of the
speculative family (a small draft model slots into the same proposer seam
later).

A proposer is any callable ``(stream, k) -> drafts``:

- ``stream`` — the lane's known tokens so far (prompt ⊕ generated), a 1-D
  int array; the engine calls it only on decode lanes (cursor at the last
  known token) and only for greedy requests (the acceptance rule is argmax
  equality — see ``serving/core.py``);
- ``k`` — the most tokens the scheduler can afford this step (its
  ``spec_k`` knob, possibly degraded by the token budget);
- ``drafts`` — up to ``k`` proposed next tokens (a sequence of ints; empty
  means "no proposal", which costs the step nothing).

Wrong drafts are *safe* — the verify step commits exactly the longest
drafted prefix matching the model's own argmax and rolls the rest back —
so proposers should answer whenever they have a plausible match and stay
silent otherwise (a silent proposer makes the speculative engine
byte-identical in work to the plain one).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence

import numpy as np


def _match_continuation(hay: np.ndarray, pattern: np.ndarray,
                        k: int) -> Optional[np.ndarray]:
    """Most recent occurrence of ``pattern`` in ``hay`` with a non-empty
    continuation → up to ``k`` following tokens, else None.  Vectorised:
    one rolling comparison per call, no python scan over positions."""
    n = len(pattern)
    if n == 0 or len(hay) <= n:
        return None
    # windows[i] == hay[i:i+n]; exclude the final window (no continuation)
    wins = np.lib.stride_tricks.sliding_window_view(hay, n)[:-1]
    hits = np.flatnonzero((wins == pattern[None, :]).all(axis=1))
    if len(hits) == 0:
        return None
    i = int(hits[-1])                       # most recent occurrence
    return hay[i + n:i + n + k]


class NGramProposer:
    """Prompt-lookup drafts: longest-suffix n-gram match, most recent first.

    ``propose(stream, k)`` takes the stream's trailing ``n``-gram for
    ``n = max_ngram .. min_ngram`` and returns the continuation of its most
    recent *earlier* occurrence — in the lane's own stream first, then (if
    ``history`` > 0) in recently finished streams the engine published via
    :meth:`observe`.  History lookup is what makes repeated traffic
    (identical or near-identical queries — the speculative analogue of the
    shared-prefix cache) draft at near-total acceptance: the second serving
    of a request drafts straight out of the first one's token stream.

    Deterministic by construction: pure function of the streams it has
    seen, no RNG — so speculative greedy decode stays reproducible.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 history: int = 0, obs=None):
        assert max_ngram >= min_ngram >= 1
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.history = history
        self.obs = obs                      # ServingObservability
        # insertion-ordered ring of finished streams, newest last
        self._streams: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.proposals = 0                  # telemetry: non-empty proposals
        self.proposed_tokens = 0

    # ------------------------------------------------------------- lookup
    def propose(self, stream: Sequence[int], k: int) -> List[int]:
        s = np.asarray(stream, np.int64)
        if k <= 0 or len(s) < self.min_ngram:
            return []
        for n in range(min(self.max_ngram, len(s) - 0), self.min_ngram - 1,
                       -1):
            if n > len(s):
                continue
            pat = s[len(s) - n:]
            out = _match_continuation(s, pat, k)
            if out is None and self.history:
                for hist in reversed(self._streams.values()):
                    # a finished stream is all "earlier": match anywhere,
                    # including its own tail
                    wins = (np.lib.stride_tricks.sliding_window_view(hist, n)
                            if len(hist) >= n else np.zeros((0, n), np.int64))
                    hits = np.flatnonzero((wins == pat[None, :]).all(axis=1))
                    cont = None
                    for i in hits[::-1]:
                        cont = hist[int(i) + n:int(i) + n + k]
                        if len(cont):
                            break
                        cont = None
                    if cont is not None:
                        out = cont
                        break
            if out is not None and len(out):
                out = [int(t) for t in out]
                self.proposals += 1
                self.proposed_tokens += len(out)
                if self.obs is not None:
                    self.obs.spec_proposed(len(out))
                return out
        return []

    __call__ = propose

    # ------------------------------------------------------------ history
    def observe(self, stream: Sequence[int]) -> None:
        """Publish a finished request's stream into the lookup history
        (no-op unless ``history`` > 0; oldest streams fall off the ring)."""
        if not self.history:
            return
        key = len(self._streams) and next(reversed(self._streams)) or 0
        self._streams[key + 1] = np.asarray(stream, np.int64)
        while len(self._streams) > self.history:
            self._streams.popitem(last=False)
