"""Process-local metrics registry for the serving stack.

One registry is the single source of truth for everything the serving
stack reports: ``AsyncLMServer.summary()``, the ``/metrics`` Prometheus
exposition, ``--metrics-json`` snapshots, and every family in
``benchmarks/serving_bench.py`` read the same counters — nothing
re-derives aggregates from ad-hoc surfaces.

Design constraints (docs/observability.md):

* **Host-side, single-writer.**  The serve loop is the only engine
  toucher, so metric updates are plain attribute writes — no locks, no
  atomics.  Readers (the asyncio ``/metrics`` endpoint, bench snapshot
  code) run on the same thread between steps or tolerate a torn read of
  an int, which CPython makes whole anyway.
* **Off the jitted path.**  Nothing here touches jax values; callers
  pass python ints/floats they already had.
* **Windowable.**  Counters support ``snapshot()``/``delta()`` and
  histograms support count-offset percentiles, so a lifetime registry
  can serve per-pass bench windows and per-server-instance summaries
  without ever resetting (resetting would tear the Prometheus view).
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "start_metrics_server",
    "write_metrics_json",
]


LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotone float/int counter, optionally a labeled family."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1, **labels: str) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0) + amount

    def value(self, **labels: str) -> float:
        return self._series.get(_label_key(labels), 0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._series)


class Gauge:
    """Last-write-wins value, optionally a labeled family."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: str) -> None:
        self._series[_label_key(labels)] = value

    def set_max(self, value: float, **labels: str) -> None:
        key = _label_key(labels)
        if value > self._series.get(key, float("-inf")):
            self._series[key] = value

    def value(self, **labels: str) -> float:
        return self._series.get(_label_key(labels), 0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._series)


# Default Prometheus-style bucket bounds for latency-ish histograms (ms).
_DEFAULT_BOUNDS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, 2500.0, 5000.0)


class Histogram:
    """Cumulative-bucket histogram plus a bounded raw-sample reservoir.

    The buckets serve the Prometheus exposition; the reservoir serves
    exact windowed percentiles for bench arms and server summaries.
    ``percentile(q, skip=n)`` reports over observations *after* the
    first ``n`` — callers window by remembering ``count()`` at the start
    of their pass.  The reservoir is a deque capped at ``max_samples``;
    a skip that falls off the left edge degrades to "all retained
    samples", which is correct for any window newer than the cap.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: Iterable[float] = _DEFAULT_BOUNDS,
                 max_samples: int = 8192):
        self.name = name
        self.help = help
        self.bounds: Tuple[float, ...] = tuple(bounds)
        self.max_samples = max_samples
        self._count = 0
        self._sum = 0.0
        self._bucket_counts = [0] * (len(self.bounds) + 1)  # +Inf tail
        self._samples: deque = deque(maxlen=max_samples)

    def observe(self, value: float) -> None:
        self._count += 1
        self._sum += value
        for i, b in enumerate(self.bounds):
            if value <= b:
                self._bucket_counts[i] += 1
                break
        else:
            self._bucket_counts[-1] += 1
        self._samples.append(value)

    def count(self) -> int:
        return self._count

    def sum(self) -> float:
        return self._sum

    def mean(self, skip: int = 0) -> float:
        xs = self._window(skip)
        return sum(xs) / len(xs) if xs else 0.0

    def percentile(self, q: float, skip: int = 0) -> float:
        """q in [0, 1]; nearest-rank over the retained window."""
        xs = sorted(self._window(skip))
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def _window(self, skip: int) -> List[float]:
        # `skip` is a lifetime observation count; translate to an index
        # into the retained deque (older samples may have fallen off).
        dropped = self._count - len(self._samples)
        start = max(0, skip - dropped)
        if start == 0:
            return list(self._samples)
        return list(self._samples)[start:]

    def series(self) -> Dict[LabelKey, float]:  # uniform snapshot shape
        return {(): self._count}


class MetricsRegistry:
    """Get-or-create home for metric families, plus export views."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    # ------------------------------------------------------ creation --
    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get_or_create(Histogram, name, help, **kw)

    def _get_or_create(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    # ------------------------------------------------------- reading --
    def get(self, name: str):
        return self._metrics.get(name)

    def value(self, name: str, **labels: str) -> float:
        m = self._metrics.get(name)
        if m is None:
            return 0
        if isinstance(m, Histogram):
            return m.count()
        return m.value(**labels)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-able point-in-time view of every family."""
        out: Dict[str, dict] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = {
                    "type": "histogram", "help": m.help,
                    "count": m.count(), "sum": m.sum(),
                    "buckets": {str(b): c for b, c in
                                zip(list(m.bounds) + ["+Inf"],
                                    m._bucket_counts)},
                }
            else:
                out[name] = {
                    "type": m.kind, "help": m.help,
                    "series": {_label_str(k) or "": v
                               for k, v in m.series().items()},
                }
        return out

    def delta(self, since: Dict[str, dict]) -> Dict[str, float]:
        """Flat {name: now - then} for unlabeled counters (and histogram
        counts), against a prior ``snapshot()``.  The bench families
        window every pass this way."""
        out: Dict[str, float] = {}
        for name, m in self._metrics.items():
            then = since.get(name)
            if isinstance(m, Histogram):
                prev = then["count"] if then else 0
                out[name] = m.count() - prev
            elif isinstance(m, Counter):
                prev = (then or {}).get("series", {}).get("", 0)
                out[name] = m.value() - prev
        return out

    def ratio(self, num: str, den: str,
              since: Optional[Dict[str, dict]] = None) -> float:
        """num/den over a window (or lifetime), 0 when den is 0."""
        if since is not None:
            d = self.delta(since)
            n, dn = d.get(num, 0), d.get(den, 0)
        else:
            n, dn = self.value(num), self.value(den)
        return n / dn if dn else 0.0

    # ------------------------------------------------------- export --
    def prometheus_text(self) -> str:
        """Prometheus text exposition format, families sorted by name."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(list(m.bounds) + ["+Inf"],
                                m._bucket_counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{b}"}} {cum}')
                lines.append(f"{name}_sum {_fmt(m.sum())}")
                lines.append(f"{name}_count {m.count()}")
            else:
                for key, v in sorted(m.series().items()):
                    lines.append(f"{name}{_label_str(key)} {_fmt(v)}")
        return "\n".join(lines) + "\n"

    def json_text(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)


def _fmt(v: float) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def write_metrics_json(registry: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        f.write(registry.json_text())
        f.write("\n")


# ------------------------------------------------------- HTTP endpoint --

async def start_metrics_server(registry: MetricsRegistry,
                               port: int = 0, host: str = "127.0.0.1"):
    """Serve ``GET /metrics`` (Prometheus text) and ``GET /metrics.json``
    off the caller's asyncio loop.  Returns the ``asyncio.Server``; read
    the bound port from ``server.sockets[0].getsockname()[1]`` (handy
    with ``port=0`` in tests).  Deliberately minimal: one-shot HTTP/1.0
    responses, connection closed after each request — enough for a
    scraper, zero dependencies.
    """
    import asyncio

    async def handle(reader, writer):
        try:
            request = await reader.readline()
            parts = request.decode("latin-1").split()
            path = parts[1] if len(parts) >= 2 else "/"
            # drain headers
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
            if path.startswith("/metrics.json"):
                body = registry.json_text().encode()
                ctype = "application/json"
                status = "200 OK"
            elif path.startswith("/metrics"):
                body = registry.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4"
                status = "200 OK"
            else:
                body = b"not found\n"
                ctype = "text/plain"
                status = "404 Not Found"
            writer.write(
                f"HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\n"
                f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
            await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    return await asyncio.start_server(handle, host=host, port=port)
