"""Batched serving engines: continuous batching over KV/SSM caches.

Two engines share one request lifecycle (submit → admit → batched decode →
recycle):

``ServingEngine`` — slot-contiguous: B slots, each slot owns a full
``max_len`` stretch of every cache leaf.  Simple, supports every family
(SSM states, ring-buffer local windows, INT8 caches), but reserves
worst-case memory per slot and decodes against ``max_len`` rows always.

``PagedServingEngine`` — block/paged KV (``serving/paged.py``): caches live
in a page pool with free-list allocation and per-slot page tables; decode
reads pages *in place* through the table (``kernels/paged_attention``) and
writes each lane's one new KV row straight into its physical page — no
per-step gathered cache copy.  The serving-side realisation of HASTILY's
linear-memory pipelining; restricted to cache layouts where every leaf
grows with sequence length.

Both engines decode one token for all active slots per ``step()`` — compute
never waits for the slowest request, finished slots are recycled
immediately.  Sampling: greedy or temperature (per-request).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.paged import PagedKVCache, cache_batch_axes


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (Lp,) int32
    max_new: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class _EngineBase:
    """Request lifecycle shared by the slot-contiguous and paged engines."""

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int,
                 max_len: int, seed: int):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"{cfg.name}: encoder-only — no decode step")
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)          # per-slot next index
        self.last_tok = np.zeros(slots, np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        m = self.model

        # b=1 prefill, jitted once per prompt-length bucket
        def prefill_one(params, tokens, caches1):
            logits, caches1 = m.prefill(params, {"tokens": tokens}, caches1)
            return logits, caches1
        self._prefill = jax.jit(prefill_one)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @staticmethod
    def greedy_token(logits: jax.Array) -> int:
        """Deterministic greedy pick: the *lowest* index among joint maxima.

        ``argmax`` tie behaviour is backend-defined; serving promises
        reproducible token streams across engines and platforms, so exact
        logit ties break to the lowest token id explicitly.
        """
        lg = jnp.asarray(logits)
        v = lg.shape[-1]
        hit = lg == jnp.max(lg)
        return int(jnp.min(jnp.where(hit, jnp.arange(v), v)))

    def _sample(self, logits: jax.Array, temperature: float) -> int:
        if temperature <= 0.0:
            return self.greedy_token(logits)
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / temperature))

    def _finish(self, req: Request) -> None:
        req.done = True
        self.finished.append(req)

    @staticmethod
    def _should_finish(req: Request, tok: int) -> bool:
        """Completion predicate, shared so both engines stay token-identical."""
        return (len(req.tokens) >= req.max_new
                or (req.eos_id is not None and tok == req.eos_id))

    def step(self) -> int:
        raise NotImplementedError

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)):
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving did not drain")
        return self.finished


class ServingEngine(_EngineBase):
    """Slot-contiguous engine: each of B slots owns ``max_len`` cache rows.

    Slot mechanics: the model's caches are batched pytrees (leading dim B).
    Prefill runs on a b=1 view and is scattered into the slot index; decode
    runs on the full batch with a *per-slot* position vector via ``jax.vmap``
    over the single-token step (dynamic_update_slice with per-example
    indices).
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        super().__init__(cfg, params, slots=slots, max_len=max_len, seed=seed)
        self.caches = self.model.init_cache(slots, max_len)
        self.axes = cache_batch_axes(self.caches)

        m = self.model
        axes = self.axes

        # batched single-token decode with per-slot positions
        def decode_all(params, toks, caches, idxs):
            def one(tok, cache, idx):
                cache1 = jax.tree.map(jnp.expand_dims, cache, axes)
                lg, c = m.decode_step(params, tok[None], cache1, idx)
                c = jax.tree.map(jnp.squeeze, c, axes)
                return lg[0], c
            return jax.vmap(one, in_axes=(0, axes, 0),
                            out_axes=(0, axes))(toks, caches, idxs)
        # donate the caches: decode rewrites one row per slot — without
        # donation every step copies the full (slots × max_len) cache.
        self._decode = jax.jit(decode_all, donate_argnums=(2,))

    def _slot_caches(self, slot: int) -> Any:
        return jax.tree.map(
            lambda a, ax: jnp.take(a, jnp.array([slot]), axis=ax),
            self.caches, self.axes)

    def _write_slot(self, slot: int, caches1: Any) -> None:
        def wr(full, one, ax):
            idx = [slice(None)] * full.ndim
            idx[ax] = slot
            return full.at[tuple(idx)].set(jnp.squeeze(one, ax))
        self.caches = jax.tree.map(wr, self.caches, caches1, self.axes)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            lp = len(req.prompt)
            assert lp + req.max_new <= self.max_len, "prompt too long"
            fresh = jax.tree.map(jnp.zeros_like, self._slot_caches(slot))
            logits, c1 = self._prefill(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None], fresh)
            self._write_slot(slot, c1)
            tok = self._sample(logits[0], req.temperature)
            req.tokens.append(int(tok))
            # the prefill's own sample may already satisfy eos/max_new
            if self._should_finish(req, int(tok)):
                self._finish(req)
                continue
            self.active[slot] = req
            self.pos[slot] = lp
            self.last_tok[slot] = int(tok)

    def step(self) -> int:
        """Admit + decode one token for every active slot.  → #active."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.last_tok, jnp.int32)
        idxs = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, self.caches,
                                           idxs)
        for s in live:
            req = self.active[s]
            tok = self._sample(logits[s], req.temperature)
            req.tokens.append(int(tok))
            self.pos[s] += 1
            self.last_tok[s] = int(tok)
            if self._should_finish(req, int(tok)):
                self._finish(req)
                self.active[s] = None           # recycle immediately
        return len(live)


class PagedServingEngine(_EngineBase):
    """Paged-KV engine: page pool + free list + per-slot page tables.

    Admission reserves each request's worst-case page count
    (ceil((prompt + max_new) / page_size)), so the lazy per-token page
    allocation during decode can never fail; physical pages are taken from
    the free list only as the sequence grows and all return on completion.

    Decode is *in place*: ``(pool, page_table, positions)`` go straight into
    the model's batched paged decode step, which writes each lane's single
    new KV row at its (physical page, in-page offset) and attends through
    the page table (``kernels/paged_attention`` — online-softmax combine
    across page blocks).  No gathered contiguous ``(B, …, P·page_size, …)``
    cache view is ever materialised; the per-step cache traffic is one read
    of the live rows plus a one-row write, instead of PR 1's
    O(B·H·Lmax·D) gather + page write-back copy.  The table is padded to a
    power-of-two width (bounds jit retraces) with the pool's scratch page;
    idle lanes point at scratch so their garbage writes never touch a live
    page, and padding slots are masked by ``kv_len`` inside the kernel.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 max_len: Optional[int] = None, seed: int = 0):
        max_len = max_len or num_pages * page_size
        super().__init__(cfg, params, slots=slots, max_len=max_len, seed=seed)
        if self.model.decode_paged is None:
            raise ValueError(
                f"paged KV cache: {cfg.name} ({cfg.family}) has no batched "
                f"paged decode step — serve it with the slot-contiguous "
                f"engine")
        self.kv = PagedKVCache(self.model, num_pages, page_size)
        self.page_tables: List[List[int]] = [[] for _ in range(slots)]
        self._reserved: List[int] = [0] * slots

        m = self.model

        def decode_paged(params, pool, tbl, toks, idxs):
            return m.decode_paged(params, toks, pool, tbl, idxs)

        # donated pool: each layer's one-row write updates in place instead
        # of copying the whole pool every step.
        self._decode = jax.jit(decode_paged, donate_argnums=(1,))

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            lp = len(req.prompt)
            assert lp + req.max_new <= self.max_len, "prompt too long"
            need = self.kv.pages_needed(lp + req.max_new)
            if need > self.kv.num_pages:
                raise ValueError(
                    f"request {req.uid} needs {need} pages "
                    f"(> pool of {self.kv.num_pages}) — raise num_pages")
            if not self.kv.can_reserve(need):
                break                      # FIFO: wait for pages to free up
            self.queue.pop(0)
            self.kv.reserve(need)
            n0 = self.kv.pages_needed(lp)
            fresh = self.model.init_cache(1, n0 * self.kv.page_size)
            logits, c1 = self._prefill(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None], fresh)
            pages = [self.kv.alloc() for _ in range(n0)]
            self.kv.write_prefill(c1, pages)
            tok = self._sample(logits[0], req.temperature)
            req.tokens.append(int(tok))
            if self._should_finish(req, int(tok)):
                self.kv.release(pages, need)
                self._finish(req)
                continue
            self.active[slot] = req
            self.pos[slot] = lp
            self.last_tok[slot] = int(tok)
            self.page_tables[slot] = pages
            self._reserved[slot] = need

    def step(self) -> int:
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        ps = self.kv.page_size
        for s in live:                       # lazy growth: one page at most
            if self.pos[s] >= len(self.page_tables[s]) * ps:
                self.page_tables[s].append(self.kv.alloc())
        width = max(len(self.page_tables[s]) for s in live)
        width = 1 << (width - 1).bit_length()          # retrace bucketing
        tbl = np.full((self.slots, width), self.kv.scratch, np.int32)
        for s in live:
            pt = self.page_tables[s]
            tbl[s, :len(pt)] = pt
        toks = jnp.asarray(self.last_tok, jnp.int32)
        idxs = jnp.asarray(
            [self.pos[s] if self.active[s] is not None else 0
             for s in range(self.slots)], jnp.int32)
        logits, self.kv.pool = self._decode(self.params, self.kv.pool,
                                            jnp.asarray(tbl), toks, idxs)
        for s in live:
            req = self.active[s]
            tok = self._sample(logits[s], req.temperature)
            req.tokens.append(int(tok))
            self.pos[s] += 1
            self.last_tok[s] = int(tok)
            if self._should_finish(req, int(tok)):
                self._finish(req)
                self.active[s] = None
                self.kv.release(self.page_tables[s], self._reserved[s])
                self.page_tables[s] = []
                self._reserved[s] = 0
        return len(live)

    @property
    def pages_in_use(self) -> int:
        return self.kv.num_pages - len(self.kv.free)
