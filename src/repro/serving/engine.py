"""Batched serving engine: slot-based continuous batching over KV/SSM caches.

The engine owns B *slots*.  Requests are admitted into free slots (prefill
writes that slot's cache), and every ``step()`` decodes one token for all
active slots in a single batched ``decode_step`` — the serving-side
expression of HASTILY's pipeline: compute never waits for the slowest
request, finished slots are recycled immediately.

Slot mechanics: the model's caches are batched pytrees (leading dim B).
Prefill runs on a b=1 view and is scattered into the slot index; decode runs
on the full batch with a *per-slot* position vector via ``jax.vmap`` over
the single-token step (dynamic_update_slice with per-example indices).
Sampling: greedy or temperature (per-request).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (Lp,) int32
    max_new: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"{cfg.name}: encoder-only — no decode step")
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.caches = self.model.init_cache(slots, max_len)
        # Per-leaf batch axis: scan-stacked (periods) cache leaves carry the
        # period dim first, so their batch axis is 1; everything else is 0.
        self.axes = jax.tree_util.tree_map_with_path(
            lambda kp, a: 1 if any(str(getattr(k, "key", "")) == "periods"
                                   for k in kp) else 0,
            self.caches)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)          # per-slot next index
        self.last_tok = np.zeros(slots, np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        m = self.model
        axes = self.axes

        # b=1 prefill, jitted once per prompt-length bucket
        def prefill_one(params, tokens, caches1):
            logits, caches1 = m.prefill(params, {"tokens": tokens}, caches1)
            return logits, caches1
        self._prefill = jax.jit(prefill_one)

        # batched single-token decode with per-slot positions
        def decode_all(params, toks, caches, idxs):
            def one(tok, cache, idx):
                cache1 = jax.tree.map(jnp.expand_dims, cache, axes)
                lg, c = m.decode_step(params, tok[None], cache1, idx)
                c = jax.tree.map(jnp.squeeze, c, axes)
                return lg[0], c
            return jax.vmap(one, in_axes=(0, axes, 0),
                            out_axes=(0, axes))(toks, caches, idxs)
        self._decode = jax.jit(decode_all)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _slot_caches(self, slot: int) -> Any:
        return jax.tree.map(
            lambda a, ax: jnp.take(a, jnp.array([slot]), axis=ax),
            self.caches, self.axes)

    def _write_slot(self, slot: int, caches1: Any) -> None:
        def wr(full, one, ax):
            idx = [slice(None)] * full.ndim
            idx[ax] = slot
            return full.at[tuple(idx)].set(jnp.squeeze(one, ax))
        self.caches = jax.tree.map(wr, self.caches, caches1, self.axes)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            lp = len(req.prompt)
            assert lp + req.max_new <= self.max_len, "prompt too long"
            fresh = jax.tree.map(jnp.zeros_like, self._slot_caches(slot))
            logits, c1 = self._prefill(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None], fresh)
            self._write_slot(slot, c1)
            tok = self._sample(logits[0], req.temperature)
            req.tokens.append(int(tok))
            # the prefill's own sample may already satisfy eos/max_new
            if (len(req.tokens) >= req.max_new
                    or (req.eos_id is not None and int(tok) == req.eos_id)):
                req.done = True
                self.finished.append(req)
                continue
            self.active[slot] = req
            self.pos[slot] = lp
            self.last_tok[slot] = int(tok)

    def _sample(self, logits: jax.Array, temperature: float) -> int:
        if temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / temperature))

    def step(self) -> int:
        """Admit + decode one token for every active slot.  → #active."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.last_tok, jnp.int32)
        idxs = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, self.caches,
                                           idxs)
        for s in live:
            req = self.active[s]
            tok = self._sample(logits[s], req.temperature)
            req.tokens.append(int(tok))
            self.pos[s] += 1
            self.last_tok[s] = int(tok)
            hit_eos = req.eos_id is not None and int(tok) == req.eos_id
            if len(req.tokens) >= req.max_new or hit_eos:
                req.done = True
                self.finished.append(req)
                self.active[s] = None           # recycle immediately
        return len(live)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)):
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving did not drain")
        return self.finished
