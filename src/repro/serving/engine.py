"""Deprecated engine classes — thin compatibility over ``EngineCore``.

The serving API is now request-level: build an
:class:`~repro.serving.core.EngineCore` and drive ``step()`` — one call
that packs chunked prefill and decode into the same paged batch (see
``serving/core.py`` and docs/architecture.md §Serving).  This module keeps
the two pre-redesign engine classes alive for one release:

``PagedServingEngine`` — a *thin shim* over ``EngineCore``: same
constructor, same ``submit``/``step``/``run`` surface, same token streams;
prefill now streams through the paged chunk step instead of the old
contiguous-prefill-then-scatter copy.

``ServingEngine`` — the slot-contiguous engine, kept whole (not a shim):
it is still the only way to serve cache layouts the page pool rejects
(ring-buffer sliding windows, SSM state — ``UnsupportedCacheLayout``).
B slots, each owning a full ``max_len`` stretch of every cache leaf;
b=1 prefill jitted per prompt-length bucket.  Prefer ``EngineCore``
wherever the layout pages.
"""
from __future__ import annotations

import warnings
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.api import Request, RequestState
from repro.serving.core import EngineCore, greedy_token
from repro.serving.paged import cache_batch_axes
from repro.serving.sampling import sample_row, stop_hit, validate_stop_tokens

__all__ = ["Request", "ServingEngine", "PagedServingEngine"]


class _EngineBase:
    """Request lifecycle of the slot-contiguous engine."""

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int,
                 max_len: int, seed: int):
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"{cfg.name}: encoder-only — no decode step")
        self.params = params
        self.slots = slots
        self.max_len = max_len
        del seed   # sampling keys are per-request now (SamplingParams.seed)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)          # per-slot next index
        self.last_tok = np.zeros(slots, np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        m = self.model

        # b=1 prefill, jitted once per prompt-length bucket — the recompile
        # cost EngineCore's chunked prefill exists to avoid.
        def prefill_one(params, tokens, caches1):
            logits, caches1 = m.prefill(params, {"tokens": tokens}, caches1)
            return logits, caches1
        self._prefill = jax.jit(prefill_one)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        validate_stop_tokens(req.sampling, self.cfg.vocab_size, uid=req.uid)
        self.queue.append(req)

    # shared with EngineCore so both surfaces stay token-identical
    greedy_token = staticmethod(greedy_token)

    def _sample(self, logits: jax.Array, req: Request) -> int:
        # per-request draw through the in-step kernel's single-lane oracle:
        # same keys, same pipeline → slot streams agree with EngineCore's
        return sample_row(logits, req.sampling, len(req.tokens))

    def _commit(self, req: Request, tok: int) -> bool:
        """Append one sampled token; → True when the request is done
        (stop sequence / eos / max_new).  A completed stop match is
        truncated from the output before it ever surfaces."""
        req.tokens.append(int(tok))
        cut = stop_hit(req.tokens, req.sampling.stop)
        if cut is not None:
            del req.tokens[cut:]
            return True
        return self._should_finish(req, int(tok))

    def _finish(self, req: Request) -> None:
        req.done = True
        req.state = RequestState.FINISHED
        self.finished.append(req)

    @staticmethod
    def _should_finish(req: Request, tok: int) -> bool:
        """Completion predicate, shared so both engines stay token-identical."""
        return (len(req.tokens) >= req.max_new
                or (req.eos_id is not None and tok == req.eos_id))

    def step(self) -> int:
        raise NotImplementedError

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)):
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving did not drain")
        return self.finished


class ServingEngine(_EngineBase):
    """Slot-contiguous engine: each of B slots owns ``max_len`` cache rows.

    Deprecated in favour of ``EngineCore`` for every pageable cache layout;
    kept whole because ring-buffer sliding-window and SSM caches cannot
    page (their per-slot state is already O(window) / O(1)).

    Slot mechanics: the model's caches are batched pytrees (leading dim B).
    Prefill runs on a b=1 view and is scattered into the slot index; decode
    runs on the full batch with a *per-slot* position vector via ``jax.vmap``
    over the single-token step (dynamic_update_slice with per-example
    indices).
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        super().__init__(cfg, params, slots=slots, max_len=max_len, seed=seed)
        self.caches = self.model.init_cache(slots, max_len)
        self.axes = cache_batch_axes(self.caches)

        m = self.model
        axes = self.axes

        # batched single-token decode with per-slot positions
        def decode_all(params, toks, caches, idxs):
            def one(tok, cache, idx):
                cache1 = jax.tree.map(jnp.expand_dims, cache, axes)
                lg, c = m.decode_step(params, tok[None], cache1, idx)
                c = jax.tree.map(jnp.squeeze, c, axes)
                return lg[0], c
            return jax.vmap(one, in_axes=(0, axes, 0),
                            out_axes=(0, axes))(toks, caches, idxs)
        # donate the caches: decode rewrites one row per slot — without
        # donation every step copies the full (slots × max_len) cache.
        self._decode = jax.jit(decode_all, donate_argnums=(2,))

    def _slot_caches(self, slot: int) -> Any:
        return jax.tree.map(
            lambda a, ax: jnp.take(a, jnp.array([slot]), axis=ax),
            self.caches, self.axes)

    def _write_slot(self, slot: int, caches1: Any) -> None:
        def wr(full, one, ax):
            idx = [slice(None)] * full.ndim
            idx[ax] = slot
            return full.at[tuple(idx)].set(jnp.squeeze(one, ax))
        self.caches = jax.tree.map(wr, self.caches, caches1, self.axes)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            lp = len(req.prompt)
            assert lp + req.max_new <= self.max_len, "prompt too long"
            fresh = jax.tree.map(jnp.zeros_like, self._slot_caches(slot))
            logits, c1 = self._prefill(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None], fresh)
            self._write_slot(slot, c1)
            tok = self._sample(logits[0], req)
            # the prefill's own sample may already satisfy stop/eos/max_new
            if self._commit(req, int(tok)):
                self._finish(req)
                continue
            req.state = RequestState.DECODE
            self.active[slot] = req
            self.pos[slot] = lp
            self.last_tok[slot] = int(tok)

    def step(self) -> int:
        """Admit + decode one token for every active slot.  → #active."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.last_tok, jnp.int32)
        idxs = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, self.caches,
                                           idxs)
        for s in live:
            req = self.active[s]
            tok = self._sample(logits[s], req)
            done = self._commit(req, int(tok))
            self.pos[s] += 1
            self.last_tok[s] = int(tok)
            if done:
                self._finish(req)
                self.active[s] = None           # recycle immediately
        return len(live)


class PagedServingEngine:
    """Deprecated shim: ``PagedServingEngine(...)`` ≡ ``EngineCore(...)``.

    One release of constructor/attribute compatibility for PR-2 callers:
    ``slots`` maps to ``lanes``, ``submit``/``step``/``run`` and the
    introspection surface (``queue``/``active``/``finished``/``kv``/
    ``pages_in_use``/``page_tables``) delegate to the core.  Token streams
    are unchanged; prefill now streams through the paged chunk step (no
    contiguous-then-scatter copy, no per-prompt-length recompiles).
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 max_len: Optional[int] = None, seed: int = 0,
                 chunk_size: Optional[int] = None,
                 prefix_cache: bool = False):
        warnings.warn(
            "PagedServingEngine is deprecated: build repro.serving.EngineCore"
            " directly (same constructor, request-level step API)",
            DeprecationWarning, stacklevel=2)
        self.core = EngineCore(cfg, params, lanes=slots, page_size=page_size,
                               num_pages=num_pages, max_len=max_len,
                               seed=seed, chunk_size=chunk_size or page_size,
                               prefix_cache=prefix_cache)
        self.cfg = cfg
        self.slots = slots

    # delegated API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.core.submit(req)

    def step(self) -> int:
        return self.core.step().lanes

    def run(self, max_steps: int = 10_000) -> List[Request]:
        return self.core.run(max_steps)

    # compat introspection --------------------------------------------------
    @property
    def kv(self):
        return self.core.kv

    @property
    def max_len(self) -> int:
        return self.core.max_len

    @property
    def finished(self) -> List[Request]:
        return self.core.finished

    @property
    def queue(self) -> List[Request]:
        return [r.req for r in self.core.scheduler.waiting]

    @property
    def active(self) -> List[Optional[Request]]:
        live: List[Optional[Request]] = [
            r.req for r in self.core.scheduler.running]
        return live + [None] * (self.slots - len(live))

    @property
    def page_tables(self) -> List[List[int]]:
        return self.core.page_tables

    @property
    def pages_in_use(self) -> int:
        return self.core.pages_in_use
