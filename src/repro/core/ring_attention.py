"""Inter-chip streaming attention — the distributed form of HASTILY §IV.

``ring_attention``: the KV sequence is sharded across a mesh axis; KV blocks flow
around the ring via ``ppermute`` while each chip's Q stays resident.  This is the
paper's fine-grained pipeline lifted one level: the "vector fed through the
pipeline" is a KV shard travelling the ICI ring, and the online max/sum rescale is
the same associative combine that makes the paper's row pipeline legal.  Because
compute on block *r* overlaps the permute of block *r+1* (XLA schedules ppermute
async), the collective cost hides behind the matmuls — the paper's
"concurrent execution of logit calculation and softmax" in ICI form.

``distributed_decode_attention``: one new token attends to a KV cache sharded over
a mesh axis (the ``long_500k`` cell).  Each shard produces partial (m, Σexp, acc)
and the partials are tree-combined — *literally* the paper's multi-core softmax
gather (§III-B2, Fig. 5), with chips as cores.

Both are ``shard_map`` bodies: call them with the relevant operands sharded over
``axis_name``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut_exp import lut_exp
from repro.parallel.compat import axis_size
from repro.core.lut_softmax import NEG_INF, softcap
from repro.core.streaming_attention import _EXP_FNS, _split_heads


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, axis_name: str, *,
                   scale: Optional[float] = None, causal: bool = False,
                   window: Optional[int] = None, cap: Optional[float] = None,
                   exp_mode: str = "lut") -> jax.Array:
    """Ring attention over a sequence-sharded KV.  Shapes are per-shard:

    q: (B, Hq, Lq_loc, D), k/v: (B, Hkv, Lkv_loc, D).  Device i owns global rows
    [i·Lq_loc, (i+1)·Lq_loc).  Returns the local (B, Hq, Lq_loc, D) output.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5
    exp_fn = _EXP_FNS[exp_mode]
    qg = _split_heads(q.astype(jnp.float32), hkv)
    q_pos = idx * lq + jnp.arange(lq, dtype=jnp.int32)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def body(carry, r):
        m, l, acc, k_blk, v_blk = carry
        src = (idx - r) % n  # original owner of the block currently resident
        kv_pos = src * lkv + jnp.arange(lkv, dtype=jnp.int32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_blk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap)
        mask = jnp.ones((lq, lkv), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None, None], exp_fn(s - m_new[..., None]), 0.0)
        alpha = exp_fn(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk, preferred_element_type=jnp.float32)
        # Rotate the KV shard one hop; overlaps with the next step's compute.
        k_blk = jax.lax.ppermute(k_blk, axis_name, fwd)
        v_blk = jax.lax.ppermute(v_blk, axis_name, fwd)
        return (m_new, l_new, acc_new, k_blk, v_blk), None

    # init derives from the (axis-varying) operands so shard_map's
    # varying-manual-axes check sees consistent carry types
    init = (jnp.full_like(qg[..., 0], NEG_INF),
            jnp.zeros_like(qg[..., 0]),
            jnp.zeros_like(qg),
            k.astype(jnp.float32), v.astype(jnp.float32))
    (m, l, acc, _, _), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(n, dtype=jnp.int32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, d).astype(q.dtype)


def distributed_decode_attention(q: jax.Array, k_cache: jax.Array,
                                 v_cache: jax.Array, axis_name: str, *,
                                 kv_len: jax.Array, scale: Optional[float] = None,
                                 window: Optional[int] = None,
                                 cap: Optional[float] = None,
                                 exp_mode: str = "lut") -> jax.Array:
    """One-token decode against a sequence-sharded KV cache (paper Fig. 5 gather).

    q: (B, Hq, 1, D) replicated over ``axis_name``; caches (B, Hkv, Lloc, D)
    sharded on L.  ``kv_len`` is the *global* number of valid cache rows.
    Returns the replicated (B, Hq, 1, D) attention output.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, hq, lq, d = q.shape
    hkv, lloc = k_cache.shape[1], k_cache.shape[2]
    if scale is None:
        scale = d ** -0.5
    exp_fn = _EXP_FNS[exp_mode]
    qg = _split_heads(q.astype(jnp.float32), hkv)
    kv_pos = idx * lloc + jnp.arange(lloc, dtype=jnp.int32)
    q_pos = kv_len - 1  # the new token's absolute position

    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    mask = kv_pos < kv_len
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)

    # --- the multi-core softmax: local partials + tree gather across chips ---
    m_loc = jnp.max(s, axis=-1)
    m = jax.lax.pmax(m_loc, axis_name)                    # tree max (O(log n))
    p = jnp.where(mask[None, None, None, None, :],
                  exp_fn(s - m[..., None]), 0.0)
    l_loc = jnp.sum(p, axis=-1)
    acc_loc = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    l = jax.lax.psum(l_loc, axis_name)                    # tree sum (O(log n))
    acc = jax.lax.psum(acc_loc, axis_name)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, d).astype(q.dtype)
